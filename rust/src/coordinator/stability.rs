//! Stability selection (Meinshausen & Bühlmann [37], cited by the paper
//! as the resampling workload that makes scalability "prohibitive"
//! without HP-CONCORD): fit the estimator on many row subsamples and
//! keep the edges selected in at least a `threshold` fraction of them.
//!
//! This is the second first-class coordinator workload (after the λ
//! grid): B independent fits batched over the worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::Result;

use crate::concord::executor::{split_by_counts, ExecutorJob, FabricExecutor};
use crate::concord::screened_dist::{batch_setup, plan_job_tasks, reassemble_job, solves_view};
use crate::concord::{fit_single_node, screen_streamed_src, ConcordConfig, ScreenedDistOptions};
use crate::io::XSource;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::simnet::cost::{CostSummary, GridBill};

/// Stability-selection configuration.
#[derive(Debug, Clone, Copy)]
pub struct StabilityConfig {
    /// Number of subsample fits B.
    pub subsamples: usize,
    /// Fraction of rows per subsample (M&B use 0.5).
    pub fraction: f64,
    /// Selection frequency threshold π (M&B recommend 0.6–0.9).
    pub threshold: f64,
    pub seed: u64,
    pub workers: usize,
}

impl Default for StabilityConfig {
    fn default() -> Self {
        StabilityConfig { subsamples: 20, fraction: 0.5, threshold: 0.7, seed: 0, workers: 2 }
    }
}

/// Result: per-edge selection frequencies and the stable edge set.
#[derive(Debug)]
pub struct StabilityOutcome {
    /// Selection frequency of each (i, j) pair, i < j, in [0, 1];
    /// row-major upper triangle.
    pub frequency: Mat,
    /// Stable edges (frequency ≥ threshold).
    pub edges: Vec<(usize, usize)>,
    pub subsamples: usize,
}

/// Row indices of subsample `b`: one reproducible stream per index,
/// shared by the single-node and distributed paths (so both draw the
/// *same* subsamples for a given seed). Public so wiring tests (and
/// downstream analyses) can rebuild exactly the subsample a fit saw.
pub fn subsample_rows(n: usize, m: usize, seed: u64, b: usize) -> Vec<usize> {
    let mut rng = Rng::new(seed ^ (0x5AB1E ^ (b as u64) << 20));
    rng.sample_indices(n, m)
}

/// The stable edge set: upper-triangle pairs selected in at least a
/// `threshold` fraction of subsamples.
fn stable_edges(freq: &Mat, threshold: f64) -> Vec<(usize, usize)> {
    let p = freq.rows();
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if freq.get(i, j) >= threshold {
                edges.push((i, j));
            }
        }
    }
    edges
}

/// Run stability selection with the worker pool.
pub fn stability_selection(
    x: &Mat,
    base: &ConcordConfig,
    cfg: &StabilityConfig,
) -> StabilityOutcome {
    let (n, p) = x.shape();
    let m = ((n as f64) * cfg.fraction).round().max(2.0) as usize;
    let x = Arc::new(x.clone());
    let base = *base;
    let scfg = *cfg;
    let next = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<Mat>();

    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let x = Arc::clone(&x);
        let next = Arc::clone(&next);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let b = next.fetch_add(1, Ordering::SeqCst);
            if b >= scfg.subsamples {
                break;
            }
            let rows = subsample_rows(n, m, scfg.seed, b);
            let sub = Mat::from_fn(m, p, |i, j| x.get(rows[i], j));
            let fit = fit_single_node(&sub, &base).expect("stability fit");
            // Indicator of selected off-diagonal support.
            let mut ind = Mat::zeros(p, p);
            for i in 0..p {
                for j in 0..p {
                    if i != j && fit.omega.get(i, j) != 0.0 {
                        ind.set(i, j, 1.0);
                    }
                }
            }
            tx.send(ind).expect("leader gone");
        }));
    }
    drop(tx);

    let mut freq = Mat::zeros(p, p);
    for ind in rx {
        freq.add_scaled(1.0 / cfg.subsamples as f64, &ind);
    }
    for h in handles {
        h.join().expect("stability worker panicked");
    }

    let edges = stable_edges(&freq, cfg.threshold);
    StabilityOutcome { frequency: freq, edges, subsamples: cfg.subsamples }
}

/// Result of distributed screened stability selection: frequencies and
/// stable edges as in [`StabilityOutcome`], plus the metered bill.
#[derive(Debug)]
pub struct StabilityDistOutcome {
    /// Selection frequency of each (i, j) pair in [0, 1].
    pub frequency: Mat,
    /// Stable edges (frequency ≥ threshold).
    pub edges: Vec<(usize, usize)>,
    pub subsamples: usize,
    /// Grid-level billing view: the per-subsample screening passes
    /// (each subsample owns its data, so screening cannot be shared —
    /// passes fold serially into `screen`), the shared cross-subsample
    /// wave schedule's critical path (`waves`), and per-subsample
    /// serial views of each fit's metered fabrics.
    pub bill: GridBill,
    /// Convenience: `bill.total()` — the whole run's bill.
    pub cost: CostSummary,
}

/// Stability selection over the screened **distributed** solver, with
/// the *batch* as the scheduling unit: every subsample is screened on
/// its own fabric (its data is its own, so the pass cannot be
/// amortized), but every (subsample, component) solve is submitted as
/// one job-tagged task into **one shared wave schedule**
/// ([`crate::concord::executor::FabricExecutor`]) under the rank
/// budget in `base.ranks_budget` — waves may mix fabrics from
/// different subsamples, so small per-subsample components no longer
/// leave the machine idle. Subsample estimates are reassembled in
/// index order from the same reproducible row subsamples as
/// [`stability_selection`] ([`subsample_rows`]), so the outcome is
/// deterministic given the seed — and bit-identical to fitting each
/// subsample standalone (`rust/tests/grid_schedule.rs`;
/// `cfg.workers` is ignored here).
///
/// Memory: each dense subsample copy lives only for its own screening
/// pass; solves rebuild their sub-matrices lazily from row-index views
/// of `x` ([`ExecutorJob`]), so peak residency is ~one subsample copy
/// rather than all B at once — bit-identical either way
/// (`rust/tests/memory_budget.rs`).
/// Takes either X backend — the CLI's stability path with `--x-file`
/// lands here via [`XSource::OnDisk`]. Each subsample is materialized
/// through [`XSource::subsample`] (a lazy row gather: on disk only the
/// m × p subsample and one read row are ever resident) and the
/// component solves rebuild their sub-matrices through the same
/// source. Determinism rule 8: the gathered rows are bit-for-bit the
/// in-core rows, so frequencies, edges and counters are
/// backend-invariant.
pub fn stability_selection_dist(
    x: XSource<'_>,
    base: &ConcordConfig,
    cfg: &StabilityConfig,
    opts: &ScreenedDistOptions,
) -> Result<StabilityDistOutcome> {
    let (n, p) = (x.rows(), x.cols());
    let m = ((n as f64) * cfg.fraction).round().max(2.0) as usize;
    let setup = batch_setup(p, base, opts)?;

    // Screen every subsample (serially billed), planning its components
    // into the shared task list as we go. Each dense subsample copy is
    // materialized only for its own screening pass and dropped at the
    // end of the iteration — the executor rebuilds the per-task
    // sub-matrices lazily from the retained row-index lists
    // ([`ExecutorJob::rows`]), so peak residency is ~one subsample, not
    // B of them, and the rebuild is bit-identical to solving from the
    // retained copy.
    let mut bill = GridBill::default();
    let mut levels = Vec::with_capacity(cfg.subsamples);
    let mut row_lists: Vec<Vec<usize>> = Vec::with_capacity(cfg.subsamples);
    let mut tasks = Vec::new();
    let mut tasks_per_job = Vec::with_capacity(cfg.subsamples);
    for b in 0..cfg.subsamples {
        let rows = subsample_rows(n, m, cfg.seed, b);
        let sub = x.subsample(&rows)?;
        let mut pass = screen_streamed_src(
            XSource::InCore(&sub),
            std::slice::from_ref(&base.lambda1),
            setup.screen_ranks,
            opts.machine,
            setup.threads,
            opts.gram_block,
        )?;
        bill.screen.merge_sequential(&pass.cost);
        let level = pass.levels.pop().expect("one threshold, one level");
        let job_tasks = plan_job_tasks(b, &level, m, base, opts);
        tasks_per_job.push(job_tasks.len());
        tasks.extend(job_tasks);
        levels.push((level, pass.diag));
        row_lists.push(rows);
        // `sub` drops here: screening holds one dense copy at a time.
    }

    // One shared cross-subsample schedule for every component solve;
    // each job is a lazy row view into the original x.
    let exec_jobs: Vec<ExecutorJob<'_>> = row_lists
        .into_iter()
        .map(|rows| ExecutorJob { x, cfg: *base, rows: Some(rows) })
        .collect();
    let executor = FabricExecutor {
        budget: setup.budget,
        mem_budget: base.mem_budget,
        threads: setup.threads,
        machine: opts.machine,
        sequential: opts.sequential,
    };
    let run = executor.run(&exec_jobs, tasks)?;
    bill.waves = run.cost;

    // Reassemble per subsample in index order; the frequency matrix
    // accumulates in that fixed order whatever the launch order was.
    let mut freq = Mat::zeros(p, p);
    let groups = split_by_counts(run.outcomes, &tasks_per_job);
    for (b, outs) in groups.into_iter().enumerate() {
        let (level, diag) = &levels[b];
        let (screened, solves) = reassemble_job(&level.components, diag, base.lambda2, outs);
        bill.per_job.push(solves_view(&solves));
        for i in 0..p {
            for j in 0..p {
                if i != j && screened.fit.omega.get(i, j) != 0.0 {
                    freq.set(i, j, freq.get(i, j) + 1.0 / cfg.subsamples as f64);
                }
            }
        }
    }
    let edges = stable_edges(&freq, cfg.threshold);
    let cost = bill.total();
    Ok(StabilityDistOutcome { frequency: freq, edges, subsamples: cfg.subsamples, bill, cost })
}

/// Deprecated `&Mat` shim for [`stability_selection_dist`] — kept one
/// release for out-of-tree callers of the pre-`XSource` signature.
#[deprecated(since = "0.2.0", note = "use stability_selection_dist(XSource::InCore(x), ..)")]
pub fn stability_selection_dist_mat(
    x: &Mat,
    base: &ConcordConfig,
    cfg: &StabilityConfig,
    opts: &ScreenedDistOptions,
) -> Result<StabilityDistOutcome> {
    stability_selection_dist(XSource::InCore(x), base, cfg, opts)
}

/// Deprecated alias from when the `XSource` entry point was the `_src`
/// twin of a `&Mat` wrapper; [`stability_selection_dist`] *is* that
/// function now.
#[deprecated(since = "0.2.0", note = "renamed to stability_selection_dist")]
pub fn stability_selection_dist_src(
    x: XSource<'_>,
    base: &ConcordConfig,
    cfg: &StabilityConfig,
    opts: &ScreenedDistOptions,
) -> Result<StabilityDistOutcome> {
    stability_selection_dist(x, base, cfg, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::metrics;
    use crate::rng::Rng;

    fn base_cfg() -> ConcordConfig {
        ConcordConfig {
            lambda1: 0.3,
            lambda2: 0.05,
            tol: 1e-4,
            max_iter: 120,
            variant: Variant::Cov,
            ..Default::default()
        }
    }

    #[test]
    fn frequencies_are_probabilities_and_symmetricish() {
        let mut rng = Rng::new(1);
        let prob = gen::chain_problem(12, 200, &mut rng);
        let out = stability_selection(
            &prob.x,
            &base_cfg(),
            &StabilityConfig { subsamples: 8, workers: 3, ..Default::default() },
        );
        for i in 0..12 {
            for j in 0..12 {
                let f = out.frequency.get(i, j);
                assert!((0.0..=1.0 + 1e-12).contains(&f));
            }
        }
        // Estimates are symmetric, so frequencies are too.
        assert!(out.frequency.max_abs_diff(&out.frequency.transpose()) < 1e-12);
    }

    #[test]
    fn stable_edges_favor_true_support() {
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(14, 600, &mut rng);
        let out = stability_selection(
            &prob.x,
            &base_cfg(),
            &StabilityConfig { subsamples: 12, threshold: 0.8, workers: 2, ..Default::default() },
        );
        assert!(!out.edges.is_empty(), "no stable edges found");
        // Build the stable-support estimate and score it.
        let mut est = Mat::eye(14);
        for &(i, j) in &out.edges {
            est.set(i, j, 1.0);
            est.set(j, i, 1.0);
        }
        let m = metrics::support_metrics(&est, &prob.omega0, 0.5);
        assert!(m.ppv > 0.9, "stability PPV {}", m.ppv);
    }

    /// The distributed screened variant is deterministic given the
    /// seed, returns probabilities, and meters the screening fabrics it
    /// ran (the screening pass alone guarantees a nonzero bill).
    #[test]
    fn dist_variant_is_deterministic_and_metered() {
        use crate::simnet::MachineParams;
        let mut rng = Rng::new(4);
        let prob = gen::chain_problem(10, 120, &mut rng);
        let cfg = StabilityConfig { subsamples: 4, workers: 1, seed: 11, ..Default::default() };
        // β_mem = 0: planning must not race other tests' tile installs.
        let machine = MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() };
        let opts = ScreenedDistOptions { total_ranks: 4, machine, ..Default::default() };
        let a = stability_selection_dist(XSource::InCore(&prob.x), &base_cfg(), &cfg, &opts)
            .unwrap();
        let b = stability_selection_dist(XSource::InCore(&prob.x), &base_cfg(), &cfg, &opts)
            .unwrap();
        assert!(a.frequency.max_abs_diff(&b.frequency) == 0.0);
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.cost.total, b.cost.total);
        assert!(a.cost.total.messages > 0, "screening passes must be metered");
        for i in 0..10 {
            for j in 0..10 {
                let f = a.frequency.get(i, j);
                assert!((0.0..=1.0 + 1e-12).contains(&f));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(3);
        let prob = gen::chain_problem(10, 120, &mut rng);
        let cfg = StabilityConfig { subsamples: 6, workers: 3, seed: 9, ..Default::default() };
        let a = stability_selection(&prob.x, &base_cfg(), &cfg);
        let b = stability_selection(&prob.x, &base_cfg(), &cfg);
        assert!(a.frequency.max_abs_diff(&b.frequency) == 0.0);
        assert_eq!(a.edges, b.edges);
    }
}
