//! Leader/worker (λ₁, λ₂)-grid sweeps.
//!
//! The leader pushes every grid point into a shared queue; `workers`
//! worker threads claim jobs, fit CONCORD, and send results back over a
//! channel. Estimates are returned with their jobs so downstream stages
//! (clustering, stability selection) can consume them; results are
//! re-ordered by job id, so the output is deterministic regardless of
//! scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use crate::concord::executor::{split_by_counts, ExecutorJob, ExecutorTask, FabricExecutor};
use crate::concord::screened_dist::{
    batch_setup, plan_job_tasks, reassemble_job, solves_view, BatchSetup,
};
use crate::concord::MultiScreenPass;
use crate::concord::screening::{fit_with_screening_on, nested_components, Components};
use crate::concord::{fit_screened_distributed, fit_single_node, ConcordConfig, ConcordFit};
use crate::concord::{screen_streamed_src, ScreenedDistOptions};
use crate::cost::schedule::ConcurrentSchedule;
use crate::io::XSource;
use crate::linalg::Mat;
use crate::runtime::native;
use crate::simnet::cost::{CostSummary, GridBill};

/// A (λ₁, λ₂) grid specification.
#[derive(Debug, Clone)]
pub struct GridSpec {
    pub lambda1: Vec<f64>,
    pub lambda2: Vec<f64>,
}

impl GridSpec {
    /// All grid points, λ₂-major (the paper's table layout).
    pub fn jobs(&self, base: &ConcordConfig) -> Vec<SweepJob> {
        let mut jobs = Vec::with_capacity(self.lambda1.len() * self.lambda2.len());
        for (i, &l1) in self.lambda1.iter().enumerate() {
            for (j, &l2) in self.lambda2.iter().enumerate() {
                let mut cfg = *base;
                cfg.lambda1 = l1;
                cfg.lambda2 = l2;
                jobs.push(SweepJob { id: jobs.len(), grid_pos: (i, j), cfg });
            }
        }
        jobs
    }
}

/// One grid point to fit.
#[derive(Debug, Clone, Copy)]
pub struct SweepJob {
    pub id: usize,
    /// (λ₁ index, λ₂ index) in the grid.
    pub grid_pos: (usize, usize),
    pub cfg: ConcordConfig,
}

/// A fitted grid point.
#[derive(Debug)]
pub struct SweepResult {
    pub job: SweepJob,
    pub fit: ConcordFit,
    /// Off-diagonal density of the estimate in [0, 1].
    pub density: f64,
    /// Which worker fitted it (observability; scheduling-dependent).
    pub worker: usize,
}

/// Aggregate outcome of a sweep.
#[derive(Debug)]
pub struct SweepOutcome {
    /// Results sorted by job id (grid order) — deterministic.
    pub results: Vec<SweepResult>,
    pub workers: usize,
}

/// Off-diagonal density of an estimate in [0, 1] (the quantity model
/// selection targets); the `max(1)` guards the p ≤ 1 degenerate grid.
fn offdiag_density(omega: &Mat) -> f64 {
    let p = omega.rows();
    let offdiag_nnz = omega.nnz().saturating_sub(p);
    offdiag_nnz as f64 / (p * p - p).max(1) as f64
}

/// The shared leader/worker pool: `workers` threads claim jobs off an
/// atomic cursor, fit them with `fit_job`, and results come back sorted
/// by job id — deterministic regardless of scheduling. Both the plain
/// and the screened sweep are thin wrappers over this.
fn sweep_pool(
    jobs: Vec<SweepJob>,
    workers: usize,
    fit_job: impl Fn(&SweepJob) -> ConcordFit + Send + Sync + 'static,
) -> Vec<SweepResult> {
    assert!(workers >= 1);
    let jobs = Arc::new(jobs);
    let fit_job = Arc::new(fit_job);
    let next = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<SweepResult>();

    let mut handles = Vec::new();
    for worker in 0..workers {
        let jobs = Arc::clone(&jobs);
        let fit_job = Arc::clone(&fit_job);
        let next = Arc::clone(&next);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            loop {
                let idx = next.fetch_add(1, Ordering::SeqCst);
                if idx >= jobs.len() {
                    break;
                }
                let job = jobs[idx];
                let fit = (*fit_job)(&job);
                let density = offdiag_density(&fit.omega);
                tx.send(SweepResult { job, fit, density, worker }).expect("leader gone");
            }
        }));
    }
    drop(tx);

    let mut results: Vec<SweepResult> = rx.into_iter().collect();
    for h in handles {
        h.join().expect("worker panicked");
    }
    results.sort_by_key(|r| r.job.id);
    results
}

/// Run the sweep with a worker pool. Every job is fitted exactly once;
/// results come back in grid order.
pub fn run_sweep(
    x: &Mat,
    grid: &GridSpec,
    base: &ConcordConfig,
    workers: usize,
) -> SweepOutcome {
    let x = Arc::new(x.clone());
    let results = sweep_pool(grid.jobs(base), workers, move |job| {
        fit_single_node(&x, &job.cfg).expect("sweep fit failed")
    });
    SweepOutcome { results, workers }
}

/// Aggregate outcome of a screened sweep.
#[derive(Debug)]
pub struct ScreenedSweepOutcome {
    /// Results sorted by job id (grid order) — deterministic.
    pub results: Vec<SweepResult>,
    pub workers: usize,
    /// Component count at each λ₁ (aligned with the grid's λ₁ list).
    pub components_per_l1: Vec<usize>,
}

/// [`run_sweep`] with covariance screening, amortized across the grid:
/// the gram matrix is formed **once**, and the component decompositions
/// for the whole λ₁ list come from one nested-refinement pass
/// ([`nested_components`] — the threshold graphs are nested, so finer
/// levels only rescan inside coarser components). Workers then solve
/// each (λ₁, λ₂) job per component via
/// [`fit_with_screening_on`], sharing the precomputed structure; the
/// λ₂ axis reuses its λ₁'s decomposition for free. Results are
/// bit-identical to calling `fit_with_screening` per grid point.
///
/// ```
/// use hpconcord::concord::{ConcordConfig, Variant};
/// use hpconcord::coordinator::{run_sweep_screened, GridSpec};
/// use hpconcord::prelude::*;
///
/// let mut rng = Rng::new(9);
/// let problem = gen::chain_problem(16, 60, &mut rng);
/// let grid = GridSpec { lambda1: vec![0.3, 0.5], lambda2: vec![0.0] };
/// let base = ConcordConfig { max_iter: 60, variant: Variant::Cov, ..Default::default() };
/// let out = run_sweep_screened(&problem.x, &grid, &base, 2);
/// assert_eq!(out.results.len(), 2); // one fit per (λ₁, λ₂) grid point
/// assert_eq!(out.components_per_l1.len(), 2); // one decomposition per λ₁
/// ```
pub fn run_sweep_screened(
    x: &Mat,
    grid: &GridSpec,
    base: &ConcordConfig,
    workers: usize,
) -> ScreenedSweepOutcome {
    // Blocking shape, kernel lane and pinning for the shared gram pass
    // (throughput only; the per-job fits re-install the same values).
    crate::linalg::tile::install(base.tile);
    crate::linalg::simd::install(base.kernel);
    crate::util::pool::set_pin_cores(base.pin_cores);
    let s = Arc::new(native::gram_mt(x, base.threads.max(1)));
    let comps: Arc<Vec<Components>> = Arc::new(nested_components(&s, &grid.lambda1));
    let components_per_l1 = comps.iter().map(|c| c.count).collect();
    let x = Arc::new(x.clone());
    let results = sweep_pool(grid.jobs(base), workers, move |job| {
        fit_with_screening_on(&x, &s, &comps[job.grid_pos.0], &job.cfg)
            .expect("screened sweep fit failed")
            .fit
    });
    ScreenedSweepOutcome { results, workers, components_per_l1 }
}

/// How a screened distributed sweep schedules the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GridSchedule {
    /// The grid is the scheduling unit (the default): **one** amortized
    /// distributed screening pass covers the whole λ₁ list, and every
    /// (grid point, component) pair is submitted into one shared
    /// cross-job wave schedule — waves may mix fabrics from different
    /// grid points. Results are bit-identical to [`PerPoint`]
    /// (`rust/tests/grid_schedule.rs`); only the bill shrinks.
    ///
    /// [`PerPoint`]: GridSchedule::PerPoint
    #[default]
    Packed,
    /// Every grid point runs standalone
    /// ([`fit_screened_distributed`](crate::concord::fit_screened_distributed)):
    /// its own screening pass, its own waves, points one after another —
    /// the pre-amortization behavior, kept as the billing baseline and
    /// equivalence reference.
    PerPoint,
}

/// Aggregate outcome of a screened *distributed* sweep.
#[derive(Debug)]
pub struct ScreenedDistSweepOutcome {
    /// Results in grid order (reassembled per job in job order).
    pub results: Vec<SweepResult>,
    /// Component count at each grid point, aligned with `results`.
    pub components: Vec<usize>,
    /// The executed wave schedule(s): one shared cross-job schedule
    /// under [`GridSchedule::Packed`], one per grid point under
    /// [`GridSchedule::PerPoint`].
    pub schedules: Vec<ConcurrentSchedule>,
    /// Grid-level billing view: the screening share (one amortized pass
    /// when packed; every point's own pass folded serially otherwise),
    /// the executed schedule's critical path, and per-job serial views
    /// of each point's metered fabric solves.
    pub bill: GridBill,
    /// Convenience: `bill.total()` — the sweep's whole bill.
    pub cost: CostSummary,
}

/// The screened sweep on the distributed path: the same per-component
/// planner and wave packer the single-point solver uses, with the rank
/// budget threaded through `base.ranks_budget`. Under the default
/// [`GridSchedule::Packed`] the whole grid is the scheduling unit —
/// one amortized screening pass (the gram and the labeling collective
/// are billed **once** for the entire λ₁ list, the distributed analogue
/// of [`run_sweep_screened`]'s nested-components reuse) and one shared
/// wave schedule over every (grid point, component) pair. Estimates are
/// reassembled per job in job order and are bit-identical to running
/// [`fit_screened_distributed`](crate::concord::fit_screened_distributed)
/// point by point, at any budget and thread count
/// (`rust/tests/grid_schedule.rs`). Takes either X backend — the CLI's
/// `sweep --mode dist --x-file` lands here via [`XSource::OnDisk`];
/// determinism rule 8 makes the backend a schedule-only knob, so every
/// grid point's estimate, density and metered counters are bit-for-bit
/// the in-core sweep's and only the modeled source residency moves.
pub fn run_sweep_screened_dist(
    x: XSource<'_>,
    grid: &GridSpec,
    base: &ConcordConfig,
    opts: &ScreenedDistOptions,
    mode: GridSchedule,
) -> Result<ScreenedDistSweepOutcome> {
    match mode {
        GridSchedule::Packed => sweep_dist_packed(x, grid, base, opts),
        GridSchedule::PerPoint => sweep_dist_per_point(x, grid, base, opts),
    }
}

/// Deprecated `&Mat` shim for [`run_sweep_screened_dist`] — kept one
/// release for out-of-tree callers of the pre-`XSource` signature.
#[deprecated(since = "0.2.0", note = "use run_sweep_screened_dist(XSource::InCore(x), ..)")]
pub fn run_sweep_screened_dist_mat(
    x: &Mat,
    grid: &GridSpec,
    base: &ConcordConfig,
    opts: &ScreenedDistOptions,
    mode: GridSchedule,
) -> Result<ScreenedDistSweepOutcome> {
    run_sweep_screened_dist(XSource::InCore(x), grid, base, opts, mode)
}

/// Deprecated alias from when the `XSource` entry point was the `_src`
/// twin of a `&Mat` wrapper; [`run_sweep_screened_dist`] *is* that
/// function now.
#[deprecated(since = "0.2.0", note = "renamed to run_sweep_screened_dist")]
pub fn run_sweep_screened_dist_src(
    x: XSource<'_>,
    grid: &GridSpec,
    base: &ConcordConfig,
    opts: &ScreenedDistOptions,
    mode: GridSchedule,
) -> Result<ScreenedDistSweepOutcome> {
    run_sweep_screened_dist(x, grid, base, opts, mode)
}

/// The reference schedule: every grid point standalone, in job order.
fn sweep_dist_per_point(
    x: XSource<'_>,
    grid: &GridSpec,
    base: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Result<ScreenedDistSweepOutcome> {
    let mut results = Vec::new();
    let mut components = Vec::new();
    let mut schedules = Vec::new();
    let mut bill = GridBill::default();
    for job in grid.jobs(base) {
        let out = fit_screened_distributed(x, &job.cfg, opts)?;
        bill.screen.merge_sequential(&out.screen_cost);
        bill.waves.merge_sequential(&out.solve_cost);
        bill.per_job.push(solves_view(&out.solves));
        schedules.push(out.schedule);
        components.push(out.components);
        let fit = out.fit;
        let density = offdiag_density(&fit.omega);
        results.push(SweepResult { job, fit, density, worker: 0 });
    }
    let cost = bill.total();
    Ok(ScreenedDistSweepOutcome { results, components, schedules, bill, cost })
}

/// The packed schedule: one amortized screening pass + one shared
/// cross-job wave schedule for the whole grid.
fn sweep_dist_packed(
    x: XSource<'_>,
    grid: &GridSpec,
    base: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Result<ScreenedDistSweepOutcome> {
    let setup = batch_setup(x.cols(), base, opts)?;

    // One distributed gram + one metered labeling collective for the
    // whole λ₁ list; the λ₂ axis reuses its λ₁'s level for free.
    let pass = screen_streamed_src(
        x,
        &grid.lambda1,
        setup.screen_ranks,
        opts.machine,
        setup.threads,
        opts.gram_block,
    )?;
    let screen_share = pass.cost;
    sweep_dist_packed_with(x, grid, base, opts, &setup, &pass, screen_share)
}

/// The packed solve phase on a *supplied* screening pass: everything
/// after screening, with the screening share of the bill given by the
/// caller. The serve layer (`crate::serve`) enters here with a cached
/// pass and a zero share — a cache hit changes the bill only, never a
/// result bit, because the cached artifact is bit-identical to the one
/// a fresh pass would compute (determinism rule 9). `pass.levels` must
/// be aligned with `grid.lambda1` (screened at those thresholds, in
/// order).
pub(crate) fn sweep_dist_packed_with(
    x: XSource<'_>,
    grid: &GridSpec,
    base: &ConcordConfig,
    opts: &ScreenedDistOptions,
    setup: &BatchSetup,
    pass: &MultiScreenPass,
    screen_share: CostSummary,
) -> Result<ScreenedDistSweepOutcome> {
    // Plan each λ₁ level once — plans depend on the level (and the
    // shared variant/threads), never on λ₂ — then re-tag the level's
    // tasks for every job that shares it: exactly the plans the
    // standalone client would compute, without repeating the
    // replication search per λ₂ value.
    let level_tasks: Vec<Vec<ExecutorTask>> = pass
        .levels
        .iter()
        .map(|level| plan_job_tasks(0, level, x.rows(), base, opts))
        .collect();
    let jobs = grid.jobs(base);
    let exec_jobs: Vec<ExecutorJob<'_>> =
        jobs.iter().map(|job| ExecutorJob { x, cfg: job.cfg, rows: None }).collect();
    let mut tasks = Vec::new();
    let mut tasks_per_job = Vec::with_capacity(jobs.len());
    for job in &jobs {
        let mut job_tasks = level_tasks[job.grid_pos.0].clone();
        for task in &mut job_tasks {
            task.tag.job = job.id;
        }
        tasks_per_job.push(job_tasks.len());
        tasks.extend(job_tasks);
    }
    let executor = FabricExecutor {
        budget: setup.budget,
        mem_budget: base.mem_budget,
        threads: setup.threads,
        machine: opts.machine,
        sequential: opts.sequential,
    };
    let run = executor.run(&exec_jobs, tasks)?;

    // Reassemble per job in job order: accumulation order is a function
    // of each job's decomposition only, so cross-job packing is
    // invisible in every estimate.
    let groups = split_by_counts(run.outcomes, &tasks_per_job);
    let mut results = Vec::with_capacity(jobs.len());
    let mut components = Vec::with_capacity(jobs.len());
    let mut per_job = Vec::with_capacity(jobs.len());
    for (job, outs) in jobs.iter().zip(groups) {
        let level = &pass.levels[job.grid_pos.0];
        let (screened, solves) =
            reassemble_job(&level.components, &pass.diag, job.cfg.lambda2, outs);
        per_job.push(solves_view(&solves));
        components.push(level.components.count);
        let density = offdiag_density(&screened.fit.omega);
        results.push(SweepResult { job: *job, fit: screened.fit, density, worker: 0 });
    }
    let bill = GridBill { screen: screen_share, waves: run.cost, per_job };
    let cost = bill.total();
    Ok(ScreenedDistSweepOutcome {
        results,
        components,
        schedules: vec![run.schedule],
        bill,
        cost,
    })
}

/// Model selection: the result whose off-diagonal density is closest to
/// `target` (the paper tunes until estimates are "equally sparse" as the
/// comparison method / the expected graph degree). Takes the result
/// slice directly so every sweep flavor — plain, screened, screened
/// distributed — selects the same way; NaN densities (or a NaN target)
/// sort last under `total_cmp` instead of panicking, so a finite
/// candidate always wins when one exists.
pub fn select_by_density(results: &[SweepResult], target: f64) -> Option<&SweepResult> {
    results
        .iter()
        .min_by(|a, b| (a.density - target).abs().total_cmp(&(b.density - target).abs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::rng::Rng;
    use crate::util::proptest::check;

    fn small_problem(seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        gen::chain_problem(10, 40, &mut rng).x
    }

    fn base_cfg() -> ConcordConfig {
        ConcordConfig { max_iter: 60, tol: 1e-4, variant: Variant::Cov, ..Default::default() }
    }

    #[test]
    fn every_job_completed_exactly_once_in_grid_order() {
        let x = small_problem(1);
        let grid = GridSpec { lambda1: vec![0.1, 0.3, 0.6], lambda2: vec![0.0, 0.2] };
        let out = run_sweep(&x, &grid, &base_cfg(), 3);
        assert_eq!(out.results.len(), 6);
        for (i, r) in out.results.iter().enumerate() {
            assert_eq!(r.job.id, i);
        }
        // Grid positions bijective.
        let mut pos: Vec<(usize, usize)> = out.results.iter().map(|r| r.job.grid_pos).collect();
        pos.sort_unstable();
        pos.dedup();
        assert_eq!(pos.len(), 6);
    }

    #[test]
    fn results_independent_of_worker_count() {
        let x = small_problem(2);
        let grid = GridSpec { lambda1: vec![0.2, 0.5], lambda2: vec![0.0, 0.3] };
        let a = run_sweep(&x, &grid, &base_cfg(), 1);
        let b = run_sweep(&x, &grid, &base_cfg(), 4);
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!(ra.job.id, rb.job.id);
            assert_eq!(ra.fit.iterations, rb.fit.iterations);
            assert!(ra.fit.omega.max_abs_diff(&rb.fit.omega) == 0.0);
        }
    }

    #[test]
    fn density_decreases_along_lambda1() {
        let x = small_problem(3);
        let grid = GridSpec { lambda1: vec![0.05, 0.9], lambda2: vec![0.1] };
        let out = run_sweep(&x, &grid, &base_cfg(), 2);
        assert!(out.results[0].density >= out.results[1].density);
    }

    #[test]
    fn select_by_density_picks_closest() {
        let x = small_problem(4);
        let grid = GridSpec { lambda1: vec![0.02, 0.3, 2.0], lambda2: vec![0.0] };
        let out = run_sweep(&x, &grid, &base_cfg(), 2);
        // Huge lambda -> density 0; selecting target 0 picks it.
        let sel = select_by_density(&out.results, 0.0).unwrap();
        assert_eq!(sel.job.grid_pos.0, 2);
        // Target the densest fit.
        let dmax = out.results.iter().map(|r| r.density).fold(0.0, f64::max);
        let sel = select_by_density(&out.results, 1.0).unwrap();
        assert_eq!(sel.density, dmax);
    }

    /// The screened sweep's amortized structure (one gram + one nested
    /// component pass) must be invisible in the results: bit-identical
    /// to per-point `fit_with_screening`, at any worker count.
    #[test]
    fn screened_sweep_matches_per_point_screening() {
        use crate::concord::fit_with_screening;
        let x = small_problem(7);
        let grid = GridSpec { lambda1: vec![0.6, 0.15, 0.3], lambda2: vec![0.0, 0.2] };
        let base = base_cfg();
        let a = run_sweep_screened(&x, &grid, &base, 1);
        let b = run_sweep_screened(&x, &grid, &base, 4);
        assert_eq!(a.results.len(), 6);
        assert_eq!(a.components_per_l1.len(), 3);
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!(ra.job.id, rb.job.id);
            assert!(ra.fit.omega.max_abs_diff(&rb.fit.omega) == 0.0, "worker-count drift");
        }
        for r in &a.results {
            let direct = fit_with_screening(&x, &r.job.cfg).unwrap();
            assert!(
                r.fit.omega.max_abs_diff(&direct.fit.omega) == 0.0,
                "job {} differs from direct screening",
                r.job.id
            );
            assert_eq!(r.fit.iterations, direct.fit.iterations);
        }
        // Thresholds are nested: a larger λ₁ can only split further.
        assert!(a.components_per_l1[0] >= a.components_per_l1[2]);
        assert!(a.components_per_l1[2] >= a.components_per_l1[1]);
    }

    /// The packed screened distributed sweep reproduces the single-point
    /// screened distributed solver bit for bit at every grid point —
    /// packing and amortization are schedule-only — while its grid bill
    /// is internally consistent (`cost == bill.total()`).
    #[test]
    fn screened_dist_sweep_matches_per_point_solver() {
        use crate::simnet::MachineParams;
        let x = small_problem(9);
        let grid = GridSpec { lambda1: vec![0.2, 0.5], lambda2: vec![0.0, 0.1] };
        let base = base_cfg();
        // β_mem = 0: planning must not race other tests' tile installs.
        let machine = MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() };
        let opts = ScreenedDistOptions { total_ranks: 4, machine, ..Default::default() };
        for mode in [GridSchedule::Packed, GridSchedule::PerPoint] {
            let out =
                run_sweep_screened_dist(XSource::InCore(&x), &grid, &base, &opts, mode).unwrap();
            assert_eq!(out.results.len(), 4, "{mode:?}");
            assert_eq!(out.components.len(), 4, "{mode:?}");
            assert_eq!(out.bill.per_job.len(), 4, "{mode:?}");
            match mode {
                GridSchedule::Packed => assert_eq!(out.schedules.len(), 1),
                GridSchedule::PerPoint => assert_eq!(out.schedules.len(), 4),
            }
            for r in &out.results {
                let direct = crate::concord::fit_screened_distributed(
                    XSource::InCore(&x),
                    &r.job.cfg,
                    &opts,
                )
                .unwrap();
                assert!(
                    r.fit.omega.max_abs_diff(&direct.fit.omega) == 0.0,
                    "{mode:?}: job {} differs from the single-point solver",
                    r.job.id
                );
                assert_eq!(r.fit.iterations, direct.fit.iterations, "{mode:?}");
            }
            let total = out.bill.total();
            assert_eq!(out.cost.total, total.total, "{mode:?}");
            assert!((out.cost.time - total.time).abs() < 1e-15, "{mode:?}");
            // The packed/serial views never cross: total ≤ sequential.
            assert!(out.bill.total().time <= out.bill.sequential().time + 1e-12, "{mode:?}");
        }
    }

    /// Property: for random grids and worker counts, the sweep completes
    /// all jobs exactly once with correct (λ₁, λ₂) wiring.
    #[test]
    fn prop_sweep_invariants() {
        check(42, 6, |rng| {
            let n1 = 1 + rng.below(3) as usize;
            let n2 = 1 + rng.below(2) as usize;
            let workers = 1 + rng.below(4) as usize;
            let grid = GridSpec {
                lambda1: (0..n1).map(|i| 0.1 + 0.2 * i as f64).collect(),
                lambda2: (0..n2).map(|i| 0.1 * i as f64).collect(),
            };
            let x = small_problem(rng.next_u64());
            let mut cfg = base_cfg();
            cfg.max_iter = 10;
            let out = run_sweep(&x, &grid, &cfg, workers);
            crate::prop_assert!(
                out.results.len() == n1 * n2,
                "missing jobs: {} != {}",
                out.results.len(),
                n1 * n2
            );
            for r in &out.results {
                let (i, j) = r.job.grid_pos;
                crate::prop_assert!(
                    (r.job.cfg.lambda1 - grid.lambda1[i]).abs() < 1e-15,
                    "λ1 wiring broken"
                );
                crate::prop_assert!(
                    (r.job.cfg.lambda2 - grid.lambda2[j]).abs() < 1e-15,
                    "λ2 wiring broken"
                );
            }
            Ok(())
        });
    }
}
