//! The tuning-grid coordinator: HP-CONCORD's §5 workflow as a runtime.
//!
//! The fMRI case study fits the estimator over an 11×8 (λ₁, λ₂) grid —
//! the resampling/model-selection workload the paper's introduction
//! flags as "prohibitive" without a scalable solver. This module is the
//! leader/worker runtime for such sweeps: a leader owns the job queue,
//! workers claim (λ₁, λ₂) jobs, fit them, and stream results back;
//! model-selection helpers pick estimates by density targets or scores.
//!
//! Each job is internally solved by the single-node path or the
//! simulated-distributed path ([`crate::concord::fit_distributed`]),
//! making the coordinator the top of the full three-layer stack.

pub mod fmri;
pub mod stability;
pub mod sweep;

pub use fmri::{run_fmri_study, FmriOutcome, FmriParams, MethodScore};
pub use stability::{
    stability_selection, stability_selection_dist, subsample_rows, StabilityConfig,
    StabilityDistOutcome, StabilityOutcome,
};
pub use sweep::{
    run_sweep, run_sweep_screened, run_sweep_screened_dist, select_by_density, GridSchedule,
    GridSpec, ScreenedDistSweepOutcome, ScreenedSweepOutcome, SweepJob, SweepOutcome, SweepResult,
};
// Deprecated pre-`XSource` shims, re-exported for one release.
#[allow(deprecated)]
pub use stability::{stability_selection_dist_mat, stability_selection_dist_src};
#[allow(deprecated)]
pub use sweep::{run_sweep_screened_dist_mat, run_sweep_screened_dist_src};
