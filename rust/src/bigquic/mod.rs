//! The BigQUIC-style baseline: ℓ₁-penalized Gaussian maximum likelihood
//! by a QUIC-style second-order method (Hsieh et al. [25]).
//!
//! The paper compares HP-CONCORD against BigQUIC (Figure 4, Table 1) —
//! a *second-order* method on the Gaussian likelihood
//!
//! ```text
//!   f(Ω) = −log det Ω + tr(SΩ) + λ‖Ω_X‖₁,
//! ```
//!
//! which converges in very few outer iterations (the paper reports 5–6)
//! but pays an O(p³) Newton solve per iteration and, "by design, only
//! runs on 1 node". No BigQUIC binary exists in this environment, so we
//! implement the method itself (DESIGN.md substitutions): Newton
//! coordinate descent over an active set with an Armijo line search and
//! positive-definiteness safeguard — the QUIC algorithm, sized for the
//! single-node problems of the head-to-head benches.

pub mod quic;

pub use quic::{fit_bigquic, fit_bigquic_data, QuicConfig, QuicFit};
