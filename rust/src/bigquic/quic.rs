//! QUIC: quadratic approximation (Newton) coordinate descent for the
//! ℓ₁-penalized Gaussian MLE.
//!
//! Outer iteration k:
//! 1. W = Ω⁻¹ (Cholesky), gradient of the smooth part G = S − W.
//! 2. Free set F = {(i,j) : |G_ij| > λ or Ω_ij ≠ 0} ∪ diagonal
//!    (the active-set fixed-point heuristic that makes QUIC scale).
//! 3. Newton direction D: coordinate descent on the quadratic model
//!      min_D  tr(G D) + ½ tr(W D W D) + λ‖(Ω + D)_X‖₁
//!    maintaining U = D·W so each coordinate update is O(p).
//! 4. Armijo backtracking on Ω + αD with a positive-definite safeguard.
//!
//! Matches BigQUIC's convergence profile: a handful of outer iterations
//! (Table 1 reports 5–6), each far more expensive than a CONCORD
//! proximal step.

use anyhow::{anyhow, Result};

use crate::linalg::{cholesky, solve_lower, solve_lower_transpose, Mat};

/// QUIC configuration.
#[derive(Debug, Clone, Copy)]
pub struct QuicConfig {
    /// ℓ₁ penalty λ on off-diagonal entries.
    pub lambda: f64,
    /// Stop when the relative objective decrease falls below this.
    pub tol: f64,
    pub max_iter: usize,
    /// Coordinate-descent sweeps per Newton direction.
    pub cd_sweeps: usize,
    /// Armijo slope parameter σ.
    pub sigma: f64,
    /// Node-local worker threads for the per-iteration W = Ω⁻¹ column
    /// solves and the gram step (the coordinate-descent sweep itself is
    /// inherently sequential). Results are identical at any value.
    pub threads: usize,
}

impl Default for QuicConfig {
    fn default() -> Self {
        QuicConfig { lambda: 0.3, tol: 1e-6, max_iter: 100, cd_sweeps: 6, sigma: 1e-3, threads: 1 }
    }
}

/// A fitted QUIC estimate.
#[derive(Debug, Clone)]
pub struct QuicFit {
    pub omega: Mat,
    /// Newton (outer) iterations — the numbers Table 1 compares.
    pub iterations: usize,
    pub objective: f64,
    pub converged: bool,
}

/// Fit from a sample covariance matrix S.
pub fn fit_bigquic(s: &Mat, cfg: &QuicConfig) -> Result<QuicFit> {
    let p = s.rows();
    if s.cols() != p {
        return Err(anyhow!("S must be square"));
    }
    let mut omega = Mat::eye(p);
    let mut f_curr = objective(&omega, s, cfg.lambda)
        .ok_or_else(|| anyhow!("initial iterate not PD"))?;
    let mut converged = false;
    let mut iters = 0;

    for _k in 0..cfg.max_iter {
        iters += 1;
        let w = inverse_spd_mt(&omega, cfg.threads)?;

        // Free set from the gradient fixed-point condition.
        let lam = cfg.lambda;
        let mut free: Vec<(usize, usize)> = Vec::new();
        for i in 0..p {
            for j in i..p {
                let g = s.get(i, j) - w.get(i, j);
                if i == j || omega.get(i, j) != 0.0 || g.abs() > lam {
                    free.push((i, j));
                }
            }
        }

        // Newton direction by coordinate descent; U = D·W.
        let mut d = Mat::zeros(p, p);
        let mut u = Mat::zeros(p, p);
        for _sweep in 0..cfg.cd_sweeps {
            for &(i, j) in &free {
                // Quadratic coefficients (Hsieh et al., eq. for QUIC).
                let wij = w.get(i, j);
                let a = if i == j {
                    wij * wij
                } else {
                    wij * wij + w.get(i, i) * w.get(j, j)
                };
                // (W D W)_ij = Σ_k W_ik U_kj with U = D W.
                let mut wdw = 0.0;
                for k in 0..p {
                    wdw += w.get(i, k) * u.get(k, j);
                }
                let b = s.get(i, j) - wij + wdw;
                let c = omega.get(i, j) + d.get(i, j);
                let mu = if i == j {
                    -b / a
                } else {
                    // Soft-threshold minimizer of ½a μ² + b μ + λ|c + μ|.
                    let z = c - b / a;
                    let soft = z.signum() * (z.abs() - lam / a).max(0.0);
                    soft - c
                };
                if mu != 0.0 {
                    d.set(i, j, d.get(i, j) + mu);
                    if i != j {
                        d.set(j, i, d.get(j, i) + mu);
                    }
                    // U rows i and j pick up the symmetric D update.
                    for k in 0..p {
                        u.set(i, k, u.get(i, k) + mu * w.get(j, k));
                    }
                    if i != j {
                        for k in 0..p {
                            u.set(j, k, u.get(j, k) + mu * w.get(i, k));
                        }
                    }
                }
            }
        }

        // Armijo: f(Ω+αD) ≤ f(Ω) + σα·δ with
        // δ = tr(G D) + λ(‖Ω+D‖₁ − ‖Ω‖₁).
        let mut delta = 0.0;
        for i in 0..p {
            for j in 0..p {
                delta += (s.get(i, j) - w.get(i, j)) * d.get(i, j);
                if i != j {
                    delta += lam * ((omega.get(i, j) + d.get(i, j)).abs()
                        - omega.get(i, j).abs());
                }
            }
        }
        let mut alpha = 1.0;
        let mut stepped = false;
        for _ in 0..30 {
            let mut cand = omega.clone();
            cand.add_scaled(alpha, &d);
            if let Some(f_new) = objective(&cand, s, lam) {
                if f_new <= f_curr + cfg.sigma * alpha * delta {
                    let rel = (f_curr - f_new).abs() / f_curr.abs().max(1.0);
                    omega = cand;
                    f_curr = f_new;
                    stepped = true;
                    if rel < cfg.tol {
                        converged = true;
                    }
                    break;
                }
            }
            alpha *= 0.5;
        }
        if !stepped || converged {
            converged = converged || !stepped;
            break;
        }
    }

    Ok(QuicFit { omega, iterations: iters, objective: f_curr, converged })
}

/// Fit from raw observations (forms S = XᵀX/n first).
pub fn fit_bigquic_data(x: &Mat, cfg: &QuicConfig) -> Result<QuicFit> {
    let s = crate::runtime::native::gram_mt(x, cfg.threads.max(1));
    fit_bigquic(&s, cfg)
}

/// f(Ω) = −log det Ω + tr(SΩ) + λ‖Ω_X‖₁; None when Ω is not PD.
fn objective(omega: &Mat, s: &Mat, lambda: f64) -> Option<f64> {
    let p = omega.rows();
    let l = cholesky(omega).ok()?;
    let logdet: f64 = (0..p).map(|i| l.get(i, i).ln()).sum::<f64>() * 2.0;
    let mut tr = 0.0;
    let mut l1 = 0.0;
    for i in 0..p {
        for j in 0..p {
            tr += s.get(i, j) * omega.get(i, j);
            if i != j {
                l1 += omega.get(i, j).abs();
            }
        }
    }
    Some(-logdet + tr + lambda * l1)
}

/// Dense SPD inverse via Cholesky column solves.
#[cfg_attr(not(test), allow(dead_code))]
fn inverse_spd(a: &Mat) -> Result<Mat> {
    inverse_spd_mt(a, 1)
}

/// [`inverse_spd`] with the column solves fanned out over `threads`
/// node-local workers. The factorization is sequential; each of the p
/// column solves is an independent run of the serial substitution
/// kernels, so the inverse is bit-identical at any thread count.
fn inverse_spd_mt(a: &Mat, threads: usize) -> Result<Mat> {
    let p = a.rows();
    let l = cholesky(a)?;
    let solve_col = |j: usize| {
        let mut e = vec![0.0; p];
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        solve_lower_transpose(&l, &y)
    };
    let mut inv = Mat::zeros(p, p);
    // p³ solve work; below the spawn cutoff the column loop stays serial.
    if threads <= 1 || p < 2 || p * p * p < crate::util::pool::SPAWN_MIN_WORK {
        // Serial: write each solved column straight into the output.
        for j in 0..p {
            let col = solve_col(j);
            for i in 0..p {
                inv.set(i, j, col[i]);
            }
        }
    } else {
        // Parallel: workers return per-chunk column bundles (at most
        // one chunk of columns buffered per worker), scattered into
        // the row-major output in deterministic column order.
        let ranges = crate::util::pool::chunk_ranges(p, threads, 1);
        let chunks = crate::util::pool::par_map(&ranges, |_i, s, e| {
            (s..e).map(solve_col).collect::<Vec<_>>()
        });
        let mut j = 0;
        for chunk in chunks {
            for col in chunk {
                for i in 0..p {
                    inv.set(i, j, col[i]);
                }
                j += 1;
            }
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::metrics::support_metrics;
    use crate::rng::Rng;

    #[test]
    fn identity_covariance_gives_identity() {
        // S = I: optimum of −log det Ω + tr(Ω) is Ω = I (off-diagonals
        // killed by any λ > 0).
        let s = Mat::eye(8);
        let fit = fit_bigquic(&s, &QuicConfig { lambda: 0.2, ..Default::default() }).unwrap();
        assert!(fit.omega.max_abs_diff(&Mat::eye(8)) < 1e-6);
        assert!(fit.converged);
    }

    #[test]
    fn kkt_conditions_hold_at_solution() {
        let mut rng = Rng::new(1);
        let prob = gen::chain_problem(12, 300, &mut rng);
        let cfg = QuicConfig { lambda: 0.15, tol: 1e-9, ..Default::default() };
        let fit = fit_bigquic_data(&prob.x, &cfg).unwrap();
        let w = inverse_spd(&fit.omega).unwrap();
        let s = crate::runtime::native::gram(&prob.x);
        for i in 0..12 {
            for j in 0..12 {
                let g = s.get(i, j) - w.get(i, j);
                if i == j {
                    assert!(g.abs() < 1e-4, "diag KKT ({i},{j}): {g}");
                } else if fit.omega.get(i, j) != 0.0 {
                    let r = g + cfg.lambda * fit.omega.get(i, j).signum();
                    assert!(r.abs() < 1e-4, "active KKT ({i},{j}): {r}");
                } else {
                    assert!(g.abs() <= cfg.lambda + 1e-4, "inactive KKT ({i},{j}): {g}");
                }
            }
        }
    }

    #[test]
    fn converges_in_few_newton_iterations() {
        // Second-order behaviour: the paper's Table 1 shows BigQUIC at
        // 5-6 iterations where CONCORD needs tens-hundreds.
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(16, 200, &mut rng);
        let fit = fit_bigquic_data(
            &prob.x,
            &QuicConfig { lambda: 0.2, tol: 1e-7, ..Default::default() },
        )
        .unwrap();
        assert!(fit.converged);
        assert!(fit.iterations <= 12, "iterations {}", fit.iterations);
    }

    #[test]
    fn recovers_chain_support_reasonably() {
        let mut rng = Rng::new(3);
        let prob = gen::chain_problem(20, 2000, &mut rng);
        let fit = fit_bigquic_data(
            &prob.x,
            &QuicConfig { lambda: 0.1, ..Default::default() },
        )
        .unwrap();
        let m = support_metrics(&fit.omega, &prob.omega0, 1e-6);
        assert!(m.recall > 0.9, "recall {}", m.recall);
        assert!(m.ppv > 0.5, "ppv {}", m.ppv);
    }

    #[test]
    fn estimate_is_positive_definite_and_symmetric() {
        let mut rng = Rng::new(4);
        let prob = gen::random_problem(14, 60, 4, &mut rng);
        let fit = fit_bigquic_data(
            &prob.x,
            &QuicConfig { lambda: 0.25, ..Default::default() },
        )
        .unwrap();
        assert!(cholesky(&fit.omega).is_ok());
        assert!(fit.omega.max_abs_diff(&fit.omega.transpose()) < 1e-10);
    }

    #[test]
    fn threaded_fit_is_byte_identical_to_serial() {
        let mut rng = Rng::new(6);
        let prob = gen::chain_problem(10, 120, &mut rng);
        let base = QuicConfig { lambda: 0.2, ..Default::default() };
        let t1 = fit_bigquic_data(&prob.x, &base).unwrap();
        for threads in [2usize, 4] {
            let tn = fit_bigquic_data(&prob.x, &QuicConfig { threads, ..base }).unwrap();
            assert_eq!(t1.iterations, tn.iterations, "threads={threads}");
            assert!(t1.omega.max_abs_diff(&tn.omega) == 0.0, "threads={threads}");
            assert_eq!(t1.objective.to_bits(), tn.objective.to_bits());
        }
    }

    #[test]
    fn larger_lambda_sparser() {
        let mut rng = Rng::new(5);
        let prob = gen::random_problem(12, 100, 4, &mut rng);
        let lo = fit_bigquic_data(&prob.x, &QuicConfig { lambda: 0.05, ..Default::default() })
            .unwrap();
        let hi = fit_bigquic_data(&prob.x, &QuicConfig { lambda: 0.6, ..Default::default() })
            .unwrap();
        assert!(hi.omega.nnz() <= lo.omega.nnz());
    }
}
