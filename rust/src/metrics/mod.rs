//! Evaluation metrics: support recovery (Table 1's PPV/FDR) and the
//! modified Jaccard clustering score (supplementary §S.3.5).

pub mod jaccard;
pub mod support;

pub use jaccard::{jaccard_similarity, pairwise_jaccard};
pub use support::{support_metrics, SupportMetrics};
