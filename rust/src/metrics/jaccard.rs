//! The modified Jaccard clustering similarity (supplementary §S.3.5):
//!
//! ```text
//! Sim(C₁, C₂) = (1/max(k, ℓ)) · Σ_{(i,j) ∈ E} W_ij,
//! W_ij = |A_i ∩ B_j| / |A_i ∪ B_j|,
//! ```
//!
//! with E a maximum-weight edge *cover* of the complete bipartite graph
//! between the clusters of the two clusterings — the cover (rather than
//! a matching) resolves comparisons between clusterings of different
//! sizes. The paper computes the cover with the algorithm of Azad et
//! al. [6]; we use the classic greedy construction (every vertex keeps
//! its heaviest incident edge), which yields a valid cover and a
//! ½-approximation of the maximum weight — identical scoring semantics
//! for ranking λ-grids, which is how the paper uses the score.

use std::collections::HashSet;

/// Pairwise Jaccard weights between the clusters of two labelings.
/// Labels may be arbitrary usize ids; clusters are their equivalence
/// classes. Returns (W, k, ℓ).
pub fn pairwise_jaccard(a: &[usize], b: &[usize]) -> (Vec<Vec<f64>>, usize, usize) {
    assert_eq!(a.len(), b.len(), "clusterings must label the same items");
    let amap = relabel(a);
    let bmap = relabel(b);
    let k = amap.iter().copied().max().map_or(0, |m| m + 1);
    let l = bmap.iter().copied().max().map_or(0, |m| m + 1);
    let mut inter = vec![vec![0usize; l]; k];
    let mut asz = vec![0usize; k];
    let mut bsz = vec![0usize; l];
    for i in 0..a.len() {
        inter[amap[i]][bmap[i]] += 1;
        asz[amap[i]] += 1;
        bsz[bmap[i]] += 1;
    }
    let w = (0..k)
        .map(|i| {
            (0..l)
                .map(|j| {
                    let inx = inter[i][j];
                    if inx == 0 {
                        0.0
                    } else {
                        inx as f64 / (asz[i] + bsz[j] - inx) as f64
                    }
                })
                .collect()
        })
        .collect();
    (w, k, l)
}

fn relabel(xs: &[usize]) -> Vec<usize> {
    let mut map = std::collections::HashMap::new();
    xs.iter()
        .map(|&x| {
            let next = map.len();
            *map.entry(x).or_insert(next)
        })
        .collect()
}

/// Modified Jaccard similarity (S.3) between two clusterings.
pub fn jaccard_similarity(a: &[usize], b: &[usize]) -> f64 {
    let (w, k, l) = pairwise_jaccard(a, b);
    if k == 0 || l == 0 {
        return 0.0;
    }
    // Greedy maximum-weight edge cover: every vertex on both sides keeps
    // its heaviest incident edge; the union (deduplicated) covers all
    // vertices.
    let mut cover: HashSet<(usize, usize)> = HashSet::new();
    for (i, row) in w.iter().enumerate() {
        let j = argmax(row);
        cover.insert((i, j));
    }
    for j in 0..l {
        // First maximum (lowest index) — the same tie-break as `argmax`,
        // which makes the cover invariant under transposing W, i.e. the
        // score symmetric in (a, b).
        let mut i = 0;
        for cand in 0..k {
            if w[cand][j] > w[i][j] {
                i = cand;
            }
        }
        cover.insert((i, j));
    }
    let total: f64 = cover.iter().map(|&(i, j)| w[i][j]).sum();
    total / k.max(l) as f64
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_clusterings_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2, 2];
        assert!((jaccard_similarity(&a, &a) - 1.0).abs() < 1e-12);
        // Label permutation doesn't matter.
        let b = vec![5, 5, 9, 9, 1, 1, 1];
        assert!((jaccard_similarity(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_refinement_scores_below_one() {
        let coarse = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let fine = vec![0, 0, 1, 1, 2, 2, 3, 3];
        let s = jaccard_similarity(&coarse, &fine);
        assert!(s > 0.0 && s < 1.0, "score {s}");
    }

    #[test]
    fn single_cluster_vs_singletons_is_small() {
        let n = 10;
        let one = vec![0usize; n];
        let each: Vec<usize> = (0..n).collect();
        let s = jaccard_similarity(&one, &each);
        assert!(s < 0.2, "score {s}");
    }

    #[test]
    fn symmetric_enough() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![0, 1, 1, 2, 2, 2];
        let s1 = jaccard_similarity(&a, &b);
        let s2 = jaccard_similarity(&b, &a);
        assert!((s1 - s2).abs() < 1e-12);
    }

    #[test]
    fn weights_are_jaccard_of_sets() {
        let a = vec![0, 0, 0, 1, 1];
        let b = vec![0, 0, 1, 1, 1];
        let (w, k, l) = pairwise_jaccard(&a, &b);
        assert_eq!((k, l), (2, 2));
        // A0 = {0,1,2}, B0 = {0,1}: |∩| = 2, |∪| = 3.
        assert!((w[0][0] - 2.0 / 3.0).abs() < 1e-12);
        // A1 = {3,4}, B1 = {2,3,4}: |∩| = 2, |∪| = 3.
        assert!((w[1][1] - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn better_agreement_scores_higher() {
        let truth = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let close = vec![0, 0, 1, 1, 1, 1, 2, 2, 2]; // one item moved
        let far = vec![0, 1, 2, 0, 1, 2, 0, 1, 2]; // systematic scramble
        assert!(jaccard_similarity(&truth, &close) > jaccard_similarity(&truth, &far));
    }
}
