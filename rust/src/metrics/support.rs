//! Support-recovery metrics against the ground-truth sparsity pattern
//! (paper Table 1: positive predictive value and false discovery rate,
//! "computed by looking at the differences between the estimated and
//! true sparsity patterns"). Diagonals are excluded — the penalty, and
//! hence the recovered graph, lives on the off-diagonal entries.

use crate::linalg::{Csr, Mat};

/// Confusion counts and derived rates over off-diagonal support.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SupportMetrics {
    pub true_pos: usize,
    pub false_pos: usize,
    pub false_neg: usize,
    /// PPV = TP / (TP + FP), in [0, 1]; 1.0 when nothing is selected.
    pub ppv: f64,
    /// FDR = FP / (TP + FP) = 1 − PPV.
    pub fdr: f64,
    /// Recall = TP / (TP + FN).
    pub recall: f64,
}

/// Compare an estimate's off-diagonal support (|entry| > `tol`) against
/// the true pattern.
pub fn support_metrics(estimate: &Mat, truth: &Csr, tol: f64) -> SupportMetrics {
    let p = estimate.rows();
    assert_eq!(estimate.cols(), p);
    assert_eq!(truth.rows(), p);
    let t = truth.to_dense();
    let mut tp = 0;
    let mut fp = 0;
    let mut fneg = 0;
    for i in 0..p {
        for j in 0..p {
            if i == j {
                continue;
            }
            let est = estimate.get(i, j).abs() > tol;
            let tru = t.get(i, j) != 0.0;
            match (est, tru) {
                (true, true) => tp += 1,
                (true, false) => fp += 1,
                (false, true) => fneg += 1,
                _ => {}
            }
        }
    }
    let sel = tp + fp;
    let ppv = if sel == 0 { 1.0 } else { tp as f64 / sel as f64 };
    let rec = if tp + fneg == 0 { 1.0 } else { tp as f64 / (tp + fneg) as f64 };
    SupportMetrics {
        true_pos: tp,
        false_pos: fp,
        false_neg: fneg,
        ppv,
        fdr: 1.0 - ppv,
        recall: rec,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn truth_chain(p: usize) -> Csr {
        crate::gen::chain_precision(p)
    }

    #[test]
    fn perfect_recovery() {
        let p = 8;
        let truth = truth_chain(p);
        let m = support_metrics(&truth.to_dense(), &truth, 1e-12);
        assert_eq!(m.false_pos, 0);
        assert_eq!(m.false_neg, 0);
        assert_eq!(m.ppv, 1.0);
        assert_eq!(m.fdr, 0.0);
        assert_eq!(m.recall, 1.0);
    }

    #[test]
    fn diagonal_only_estimate_has_zero_recall_but_unit_ppv() {
        let p = 6;
        let truth = truth_chain(p);
        let m = support_metrics(&Mat::eye(p), &truth, 1e-12);
        assert_eq!(m.true_pos, 0);
        assert_eq!(m.false_pos, 0);
        assert_eq!(m.ppv, 1.0); // nothing selected, nothing wrong
        assert_eq!(m.recall, 0.0);
    }

    #[test]
    fn dense_estimate_counts_false_positives() {
        let p = 5;
        let truth = truth_chain(p);
        let dense = Mat::from_fn(p, p, |_, _| 1.0);
        let m = support_metrics(&dense, &truth, 1e-12);
        // Off-diagonal entries: p(p-1) = 20; true edges: 2(p-1) = 8.
        assert_eq!(m.true_pos, 8);
        assert_eq!(m.false_pos, 12);
        assert_eq!(m.false_neg, 0);
        assert!((m.ppv - 0.4).abs() < 1e-12);
        assert!((m.fdr - 0.6).abs() < 1e-12);
    }

    #[test]
    fn tol_filters_small_entries() {
        let p = 4;
        let truth = truth_chain(p);
        let mut est = truth.to_dense();
        est.set(0, 3, 1e-9);
        est.set(3, 0, 1e-9);
        let strict = support_metrics(&est, &truth, 1e-8);
        assert_eq!(strict.false_pos, 0);
        let loose = support_metrics(&est, &truth, 0.0);
        assert_eq!(loose.false_pos, 2);
    }
}
