//! Per-component fabric scheduling for screened solving.
//!
//! Screening splits one p×p problem into independent components; each
//! non-trivial component then deserves its *own* machine shape. This
//! module turns the Lemma 3.1–3.5 closed forms into that decision:
//! search power-of-two rank counts `P ≤ max_ranks` and every
//! fabric-runnable replication pair `(c_X, c_Ω)`, price each cell with
//! [`CostBreakdown::time_with_threads`](super::model::CostBreakdown),
//! and hand the component the cheapest `(P, c_X, c_Ω, variant)`. Small
//! components come back with `ranks == 1` — the model itself says the
//! communication would cost more than the parallelism buys, so they run
//! on the single-node path.
//!
//! On top of the per-component choice sits the **wave packer**
//! ([`plan_concurrent`]): independent component fabrics are packed onto
//! a global rank budget so they run *concurrently* instead of one after
//! another — the communication-avoiding play the Lemma 3.5 model
//! enables, and the block-solver trick of exploiting independent
//! subproblems. Every schedulable unit is **job-tagged** ([`JobTag`]):
//! a component belongs to some *job* (a grid point of a (λ₁, λ₂)
//! sweep, a stability subsample — a single fit is job 0), and the
//! packer treats the flat (job, component) list as one pool, so waves
//! may mix fabrics from different jobs. Components are taken
//! longest-processing-time first (LPT on `modeled_time`, ties broken
//! by the tag so the schedule is a pure function of its inputs) and
//! placed into the first wave with enough rank headroom; a component
//! whose plan is wider than the budget is first re-planned under the
//! narrower cap to the cheapest runnable power-of-two that fits
//! ([`shrink_to_budget`]). The resulting schedule's makespan is the
//! sum of per-wave maxima — what `CostSummary::merge_concurrent`
//! bills.
//!
//! The packer enforces a second, orthogonal budget: **memory**. Each
//! schedulable unit carries a [`MemFootprint`] — the words the executor
//! will keep resident while the task runs (its extracted X sub-matrix
//! plus the gram/omega working set) — and a wave admits a new entry
//! only while the sum of footprints stays within `mem_budget` words
//! (0 = unbounded). Because the executor extracts sub-matrices at
//! wave launch and drops them when the wave's outcomes land, the
//! schedule's peak resident memory is the largest *wave* sum
//! ([`ConcurrentSchedule::peak_mem_words`]), not the job-list sum. A
//! single task that cannot fit the memory budget on its own is a clean
//! error — shrinking ranks cannot shrink data. Both budgets are
//! schedule-only knobs (determinism rule 7): they move *when* a fabric
//! launches, never what it computes.
//!
//! The *source* of X is billed separately: a [`MemFootprint`] prices
//! what a task keeps resident, while `CostSummary::x_panel_words`
//! prices what the X backend itself holds to serve the reads — the
//! whole backing matrix for an in-core run, one read panel for an
//! on-disk one ([`crate::io::XSource::panel_words`]). It maxes (never
//! sums) across both merge directions because the source is shared by
//! everything that reads it; the X backend is a schedule-only knob too
//! (determinism rule 8), so only this residency term distinguishes an
//! on-disk bill from its bit-identical in-core twin.

use anyhow::{bail, Result};

use crate::concord::Variant;
use crate::simnet::MachineParams;

use super::model::{CostBreakdown, ProblemShape, ReplicationChoice};
use super::optimizer::evaluate;

/// The fabric one screened component is assigned. `ranks == 1` means
/// the single-node path (no fabric is spun up at all).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FabricPlan {
    pub ranks: usize,
    pub c_x: usize,
    pub c_omega: usize,
    pub variant: Variant,
    /// Lemma 3.5 modeled time of this cell (flops at `threads` workers
    /// per rank; comm terms zero when `ranks == 1`).
    pub modeled_time: f64,
}

impl FabricPlan {
    /// The trivial single-node plan (used for components below the
    /// caller's cutoff, where no model evaluation is needed).
    pub fn single_node(variant: Variant) -> Self {
        FabricPlan { ranks: 1, c_x: 1, c_omega: 1, variant, modeled_time: 0.0 }
    }
}

/// True when the 1.5D rank programs can actually *run* this cell: every
/// rotation needs `c_F | T_R` (see `dist::rotate_parts`). Both variants
/// pair the grids as `(c_X, c_Ω)` and `(c_Ω, c_X)`; Cov's one-time gram
/// step additionally rotates the Xᵀ slabs against the X grid itself,
/// pairing `(c_X, c_X)` — i.e. requiring `c_X² ≤ P` for powers of two.
pub fn runnable_on_fabric(p_ranks: usize, c_x: usize, c_omega: usize, variant: Variant) -> bool {
    let rep = ReplicationChoice { p_procs: p_ranks, c_x, c_omega };
    if !rep.valid() {
        return false;
    }
    let pair_ok = |c_r: usize, c_f: usize| (p_ranks / c_r) % c_f == 0;
    let both = pair_ok(c_x, c_omega) && pair_ok(c_omega, c_x);
    match variant {
        Variant::Obs => both,
        // Auto is priced per concrete variant by the planner; treat it
        // conservatively so the cell is runnable whichever side wins.
        Variant::Cov | Variant::Auto => both && pair_ok(c_x, c_x),
    }
}

/// Choose the fabric for one screened component of shape `shape`
/// (`shape.p` is the component size): search power-of-two rank counts
/// up to `min(max_ranks, size)` (so no team is ever empty) and all
/// runnable replication pairs, minimizing modeled time under `threads`
/// node-local workers. Ties prefer fewer ranks, then lower replication.
pub fn plan_component(
    shape: &ProblemShape,
    max_ranks: usize,
    threads: usize,
    machine: &MachineParams,
    variant: Variant,
) -> FabricPlan {
    let size = (shape.p as usize).max(1);
    let mut best: Option<FabricPlan> = None;
    let mut p_ranks = 1usize;
    while p_ranks <= max_ranks.max(1) && p_ranks <= size {
        if let Some(cand) = plan_at_ranks(shape, p_ranks, threads, machine, variant) {
            if best.map(|b| cand.modeled_time < b.modeled_time).unwrap_or(true) {
                best = Some(cand);
            }
        }
        p_ranks *= 2;
    }
    best.expect("P = 1, c_X = c_Ω = 1 is always runnable")
}

/// The cheapest runnable plan at *exactly* `p_ranks` ranks: search every
/// runnable replication pair (and both concrete variants for
/// [`Variant::Auto`]) at the fixed rank count. Ties prefer lower
/// replication (the search visits `(c_X, c_Ω)` in ascending order and
/// keeps strict improvements only).
pub fn plan_at_ranks(
    shape: &ProblemShape,
    p_ranks: usize,
    threads: usize,
    machine: &MachineParams,
    variant: Variant,
) -> Option<FabricPlan> {
    let variants: &[Variant] = match variant {
        Variant::Auto => &[Variant::Cov, Variant::Obs],
        Variant::Cov => &[Variant::Cov],
        Variant::Obs => &[Variant::Obs],
    };
    let threads = threads.max(1);
    let mut best: Option<FabricPlan> = None;
    let mut c_x = 1usize;
    while c_x <= p_ranks {
        let mut c_o = 1usize;
        while c_x * c_o <= p_ranks {
            for &v in variants {
                if runnable_on_fabric(p_ranks, c_x, c_o, v) {
                    let rep = ReplicationChoice { p_procs: p_ranks, c_x, c_omega: c_o };
                    let time = price(&evaluate(shape, &rep, v), p_ranks, threads, machine);
                    if best.map(|b| time < b.modeled_time).unwrap_or(true) {
                        best = Some(FabricPlan {
                            ranks: p_ranks,
                            c_x,
                            c_omega: c_o,
                            variant: v,
                            modeled_time: time,
                        });
                    }
                }
            }
            c_o *= 2;
        }
        c_x *= 2;
    }
    best
}

/// Shrink a plan that is wider than the wave packer's rank budget: the
/// full [`plan_component`] search is re-run under the narrower cap, so
/// the component gets the *cheapest* runnable power-of-two `P ≤ budget`
/// (best replication pair included, re-priced), not merely its old
/// shape truncated. The variant stays the one the full-width planner
/// already chose — shrinking narrows the fabric, it does not flip the
/// algorithm. `(1, 1, 1)` is always runnable, so at worst the plan
/// degenerates to the single-rank plan, which the executor routes to
/// the single-node path.
pub fn shrink_to_budget(
    shape: &ProblemShape,
    plan: FabricPlan,
    budget: usize,
    threads: usize,
    machine: &MachineParams,
) -> FabricPlan {
    let budget = budget.max(1);
    if plan.ranks <= budget {
        return plan;
    }
    plan_component(shape, budget, threads, machine, plan.variant)
}

/// Identity of one schedulable unit of work: component `component` of
/// submission `job`. Jobs number the independent problems sharing one
/// schedule — grid points of a sweep, stability subsamples; a
/// standalone fit submits everything under [`JobTag::single`] (job 0).
/// The derived ordering (job-major, then component) is the
/// deterministic LPT tie-break and the sequential-reference launch
/// order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobTag {
    pub job: usize,
    pub component: usize,
}

impl JobTag {
    /// The tag of a standalone (single-job) fit's component.
    pub fn single(component: usize) -> Self {
        JobTag { job: 0, component }
    }
}

/// Words of f64 the executor keeps resident while one task runs: the
/// extracted `n × |c|` column sub-matrix of X plus the `|c|²` gram /
/// omega working set the per-component solver allocates. The footprint
/// is a property of the *data*, not the fabric shape — replication
/// copies live on simulated ranks, while this counter models the host
/// process actually running the simulation — so shrinking a plan's
/// ranks never shrinks its footprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemFootprint {
    /// Words of the extracted X sub-matrix (`n · |c|`).
    pub x_words: u64,
    /// Words of the per-component working set (`|c|²`).
    pub work_words: u64,
}

impl MemFootprint {
    /// Footprint of a component of `size` columns drawn from an
    /// `n`-row sample matrix.
    pub fn for_component(n: usize, size: usize) -> Self {
        MemFootprint {
            x_words: (n as u64) * (size as u64),
            work_words: (size as u64) * (size as u64),
        }
    }

    /// Total resident words while the task runs.
    pub fn words(&self) -> u64 {
        self.x_words + self.work_words
    }
}

/// One schedulable unit as submitted to the packer: which (job,
/// component), the plan the per-component planner chose, the problem
/// shape (consulted only when the plan must be shrunk and re-priced),
/// and the memory footprint the executor will hold while it runs.
#[derive(Debug, Clone, Copy)]
pub struct PackItem {
    pub tag: JobTag,
    pub plan: FabricPlan,
    pub shape: ProblemShape,
    pub mem: MemFootprint,
}

/// One component's slot in a concurrent schedule: which (job,
/// component), the (possibly budget-shrunk) fabric plan it will
/// actually run, and the footprint it charges against the wave's
/// memory budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledComponent {
    /// Which job's component this is (index into the caller's screened
    /// decomposition for that job).
    pub tag: JobTag,
    pub plan: FabricPlan,
    pub mem: MemFootprint,
}

/// One wave: a set of component fabrics that run at the same time on
/// disjoint rank teams. Entries are in LPT order, so the first entry is
/// the wave's critical path.
#[derive(Debug, Clone, Default)]
pub struct Wave {
    pub entries: Vec<ScheduledComponent>,
}

impl Wave {
    /// Ranks this wave occupies (the sum of its fabrics' teams).
    pub fn ranks(&self) -> usize {
        self.entries.iter().map(|e| e.plan.ranks).sum()
    }

    /// Modeled time of the wave: the max over its concurrent fabrics.
    pub fn modeled_time(&self) -> f64 {
        self.entries.iter().map(|e| e.plan.modeled_time).fold(0.0, f64::max)
    }

    /// Resident words while this wave runs: its entries' sub-matrices
    /// and working sets are all live at once, so footprints *sum*.
    pub fn mem_words(&self) -> u64 {
        self.entries.iter().map(|e| e.mem.words()).sum()
    }
}

/// A wave-based concurrent schedule over a global rank budget.
#[derive(Debug, Clone, Default)]
pub struct ConcurrentSchedule {
    /// Waves in launch order; within a wave everything runs at once.
    pub waves: Vec<Wave>,
    /// The rank budget the waves were packed under.
    pub budget: usize,
    /// The memory budget (words) the waves were packed under; 0 means
    /// unbounded.
    pub mem_budget: u64,
}

impl ConcurrentSchedule {
    /// Modeled makespan: waves run back to back, so the schedule's
    /// critical path is the sum of per-wave maxima. Equals the serial
    /// sum of component times exactly when every wave holds one
    /// component; strictly less whenever any wave packs two or more.
    pub fn makespan(&self) -> f64 {
        self.waves.iter().map(Wave::modeled_time).sum()
    }

    /// The serial bill the same plans would cost one after another.
    pub fn sequential_time(&self) -> f64 {
        self.waves.iter().flat_map(|w| w.entries.iter()).map(|e| e.plan.modeled_time).sum()
    }

    /// Total scheduled components across all waves.
    pub fn components(&self) -> usize {
        self.waves.iter().map(|w| w.entries.len()).sum()
    }

    /// Modeled peak resident memory of the schedule: waves run back to
    /// back and each wave's footprints drop before the next launches,
    /// so the peak is the largest per-wave sum — not the sum over the
    /// whole job list.
    pub fn peak_mem_words(&self) -> u64 {
        self.waves.iter().map(Wave::mem_words).max().unwrap_or(0)
    }
}

/// Pack independent component fabrics into waves under a global rank
/// budget *and* a global memory budget, minimizing the modeled
/// makespan greedily: components are sorted longest-processing-time
/// first (ties broken by [`JobTag`], so the schedule is a pure
/// function of its inputs) and each is placed into the first wave with
/// enough rank headroom *and* enough memory headroom — because earlier
/// entries are never shorter, joining a wave never lengthens it, so
/// first-fit is makespan-optimal for the wave set the scan builds. A
/// plan wider than the rank budget is first re-planned to the cheapest
/// runnable power-of-two that fits ([`shrink_to_budget`]); every wave
/// therefore occupies at most `budget` ranks and at most `mem_budget`
/// words (`mem_budget == 0` disables the memory constraint).
///
/// Memory, unlike ranks, cannot be shrunk: a task's footprint is its
/// data. A single component whose [`MemFootprint`] alone exceeds a
/// nonzero `mem_budget` is therefore a clean error, not a panic and
/// not a silent overrun.
///
/// The input is the flat list of every submitted job's components
/// ([`PackItem`]s, the shape consulted only when a plan must be shrunk
/// and re-priced) — so a sweep's grid points and a stability run's
/// subsamples pack into the *same* waves as naturally as one fit's
/// components do.
pub fn plan_concurrent(
    components: &[PackItem],
    budget: usize,
    mem_budget: u64,
    threads: usize,
    machine: &MachineParams,
) -> Result<ConcurrentSchedule> {
    let budget = budget.max(1);
    for item in components {
        if mem_budget > 0 && item.mem.words() > mem_budget {
            bail!(
                "component (job {}, component {}) needs {} words resident \
                 but the memory budget is {} words; shrinking ranks cannot \
                 shrink data — raise --mem-budget or screen harder",
                item.tag.job,
                item.tag.component,
                item.mem.words(),
                mem_budget
            );
        }
    }
    let mut items: Vec<ScheduledComponent> = components
        .iter()
        .map(|&PackItem { tag, plan, shape, mem }| ScheduledComponent {
            tag,
            plan: shrink_to_budget(&shape, plan, budget, threads, machine),
            mem,
        })
        .collect();
    items.sort_by(|a, b| {
        b.plan.modeled_time.total_cmp(&a.plan.modeled_time).then(a.tag.cmp(&b.tag))
    });
    let mut waves: Vec<Wave> = Vec::new();
    for item in items {
        let fits = |w: &&mut Wave| {
            w.ranks() + item.plan.ranks <= budget
                && (mem_budget == 0 || w.mem_words() + item.mem.words() <= mem_budget)
        };
        match waves.iter_mut().find(fits) {
            Some(wave) => wave.entries.push(item),
            None => waves.push(Wave { entries: vec![item] }),
        }
    }
    Ok(ConcurrentSchedule { waves, budget, mem_budget })
}

/// Price one cell. At P = 1 nothing is sent — the closed forms'
/// residual L/W terms are rotation bookkeeping that degenerates to
/// self-sends — so only the flop terms count (priced at the same
/// installed-tile effective γ_dense as the fabric cells, so the
/// blocked-kernel cache-reuse term never biases the P = 1 decision).
fn price(cost: &CostBreakdown, p_ranks: usize, threads: usize, machine: &MachineParams) -> f64 {
    if p_ranks == 1 {
        let gamma_eff = machine.gamma_dense
            + crate::linalg::tile::current().gemm_words_per_flop() * machine.beta_mem;
        let flop_time = cost.flops_dense * gamma_eff + cost.flops_sparse * machine.gamma_sparse;
        flop_time / threads as f64
    } else {
        cost.time_with_threads(machine, p_ranks, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Edison with β_mem zeroed: plan comparisons across separate calls
    /// must not depend on the process-global tile shape (other tests
    /// install tiles concurrently).
    fn machine() -> MachineParams {
        MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() }
    }

    /// A tiny component: any communication dwarfs its flops, so the
    /// planner must route it to the single-node path.
    #[test]
    fn tiny_component_goes_single_node() {
        let shape = ProblemShape { p: 8.0, n: 100.0, s: 40.0, t: 10.0, d: 3.0 };
        let plan = plan_component(&shape, 64, 1, &machine(), Variant::Auto);
        assert_eq!(plan.ranks, 1);
        assert_eq!((plan.c_x, plan.c_omega), (1, 1));
    }

    /// A massive component is flop-bound: the planner should spend the
    /// whole rank budget on it.
    #[test]
    fn huge_component_takes_the_full_budget() {
        let shape = ProblemShape { p: 40_000.0, n: 100.0, s: 40.0, t: 10.0, d: 10.0 };
        let plan = plan_component(&shape, 64, 1, &machine(), Variant::Obs);
        assert_eq!(plan.ranks, 64);
        assert!(runnable_on_fabric(plan.ranks, plan.c_x, plan.c_omega, plan.variant));
    }

    /// The rank budget is never exceeded, and fabrics never outnumber
    /// the component's columns.
    #[test]
    fn plans_respect_budget_and_size() {
        let m = machine();
        for (p, max_ranks) in [(3.0, 64usize), (100.0, 8), (5_000.0, 16)] {
            let shape = ProblemShape { p, n: 50.0, s: 30.0, t: 8.0, d: 5.0 };
            let plan = plan_component(&shape, max_ranks, 4, &m, Variant::Auto);
            assert!(plan.ranks <= max_ranks);
            assert!(plan.ranks <= p as usize);
            assert!(plan.c_x * plan.c_omega <= plan.ranks);
            assert!(runnable_on_fabric(plan.ranks, plan.c_x, plan.c_omega, plan.variant));
            assert!(plan.modeled_time.is_finite());
        }
    }

    /// Cov plans honour the gram step's extra c_X² ≤ P constraint that
    /// plain `ReplicationChoice::valid` does not know about.
    #[test]
    fn runnable_enforces_cov_gram_constraint() {
        assert!(!runnable_on_fabric(8, 4, 2, Variant::Cov));
        assert!(runnable_on_fabric(8, 4, 2, Variant::Obs));
        assert!(runnable_on_fabric(16, 4, 2, Variant::Cov));
        assert!(!runnable_on_fabric(8, 4, 4, Variant::Obs), "c_X·c_Ω > P");
        assert!(runnable_on_fabric(1, 1, 1, Variant::Auto));
    }

    /// More node-local threads deflate the flop terms, so the threaded
    /// plan's modeled time can only improve.
    #[test]
    fn threads_never_hurt_the_plan() {
        let shape = ProblemShape { p: 2_000.0, n: 100.0, s: 40.0, t: 10.0, d: 10.0 };
        let m = machine();
        let t1 = plan_component(&shape, 32, 1, &m, Variant::Obs);
        let t8 = plan_component(&shape, 32, 8, &m, Variant::Obs);
        assert!(t8.modeled_time <= t1.modeled_time);
    }

    fn shapes(ps: &[f64]) -> Vec<PackItem> {
        let m = machine();
        ps.iter()
            .enumerate()
            .map(|(c, &p)| {
                let shape = ProblemShape { p, n: 80.0, s: 30.0, t: 8.0, d: 6.0 };
                PackItem {
                    tag: JobTag::single(c),
                    plan: plan_component(&shape, 16, 1, &m, Variant::Obs),
                    shape,
                    mem: MemFootprint::for_component(shape.n as usize, p as usize),
                }
            })
            .collect()
    }

    /// Every component appears in exactly one wave, no wave exceeds the
    /// budget, and entries within a wave are LPT-ordered.
    #[test]
    fn waves_respect_budget_and_cover_components() {
        let comps = shapes(&[6_000.0, 12_000.0, 3_000.0, 9_000.0, 500.0]);
        for budget in [1usize, 2, 4, 8, 16, 64] {
            let sched = plan_concurrent(&comps, budget, 0, 1, &machine()).unwrap();
            let mut seen: Vec<usize> = sched
                .waves
                .iter()
                .flat_map(|w| w.entries.iter().map(|e| e.tag.component))
                .collect();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3, 4], "budget {budget}");
            for w in &sched.waves {
                assert!(w.ranks() <= budget, "budget {budget}: wave uses {} ranks", w.ranks());
                for pair in w.entries.windows(2) {
                    assert!(
                        pair[0].plan.modeled_time >= pair[1].plan.modeled_time,
                        "budget {budget}: wave entries not LPT-ordered"
                    );
                }
            }
        }
    }

    /// The concurrent makespan never exceeds the serial sum, matches it
    /// exactly when the budget forces one component per wave, and
    /// strictly undercuts it as soon as any wave packs two fabrics.
    #[test]
    fn makespan_undercuts_serial_sum() {
        let comps = shapes(&[8_000.0, 8_000.0, 8_000.0, 8_000.0]);
        let m = machine();
        let wide = plan_concurrent(&comps, 64, 0, 1, &m).unwrap();
        let serial = wide.sequential_time();
        assert!(wide.makespan() <= serial + 1e-15);
        assert!(
            wide.waves.iter().any(|w| w.entries.len() >= 2),
            "64-rank budget must pack at least one wave"
        );
        assert!(wide.makespan() < serial, "packing must shorten the critical path");

        // A budget of one rank degenerates to one (single-rank)
        // component per wave: makespan == serial sum of the shrunk plans.
        let narrow = plan_concurrent(&comps, 1, 0, 1, &m).unwrap();
        assert!(narrow.waves.iter().all(|w| w.entries.len() == 1));
        assert!((narrow.makespan() - narrow.sequential_time()).abs() < 1e-15);
    }

    /// Plans wider than the budget are shrunk to a runnable power-of-two
    /// that fits, never dropped and never over budget.
    #[test]
    fn oversized_plans_shrink_to_fit() {
        let shape = ProblemShape { p: 40_000.0, n: 100.0, s: 40.0, t: 10.0, d: 10.0 };
        let m = machine();
        let plan = plan_component(&shape, 64, 1, &m, Variant::Obs);
        assert!(plan.ranks > 4, "fixture must want a wide fabric");
        for budget in [1usize, 2, 4, 5, 7] {
            let shrunk = shrink_to_budget(&shape, plan, budget, 1, &m);
            assert!(shrunk.ranks <= budget, "budget {budget}");
            assert!(shrunk.ranks.is_power_of_two());
            assert!(runnable_on_fabric(shrunk.ranks, shrunk.c_x, shrunk.c_omega, shrunk.variant));
            assert!(
                shrunk.modeled_time >= plan.modeled_time,
                "budget {budget}: fewer ranks cannot be modeled faster"
            );
        }
        // Plans already inside the budget pass through untouched.
        assert_eq!(shrink_to_budget(&shape, plan, plan.ranks, 1, &m), plan);
    }

    /// The schedule is a pure function of its inputs: identical calls
    /// give identical waves (LPT ties broken by the job tag).
    #[test]
    fn packing_is_deterministic() {
        let comps = shapes(&[4_000.0, 4_000.0, 4_000.0, 2_000.0]);
        let m = machine();
        let a = plan_concurrent(&comps, 8, 0, 2, &m).unwrap();
        let b = plan_concurrent(&comps, 8, 0, 2, &m).unwrap();
        assert_eq!(a.waves.len(), b.waves.len());
        for (wa, wb) in a.waves.iter().zip(&b.waves) {
            assert_eq!(wa.entries, wb.entries);
        }
        assert_eq!(a.components(), 4);
    }

    /// Tags from several jobs pack into one pool: every (job, component)
    /// pair appears exactly once, waves may mix jobs, and LPT ties
    /// break job-major then component-major.
    #[test]
    fn cross_job_packing_covers_every_tag_and_may_mix_jobs() {
        let m = machine();
        // Three jobs with identical components: all plans tie on
        // modeled_time, so the LPT order is exactly the tag order.
        let mut comps: Vec<PackItem> = Vec::new();
        for job in 0..3usize {
            for c in 0..2usize {
                let shape = ProblemShape { p: 8_000.0, n: 80.0, s: 30.0, t: 8.0, d: 6.0 };
                let plan = plan_component(&shape, 16, 1, &m, Variant::Obs);
                let mem = MemFootprint::for_component(80, 8_000);
                comps.push(PackItem { tag: JobTag { job, component: c }, plan, shape, mem });
            }
        }
        let per_fabric = comps[0].plan.ranks;
        assert!(per_fabric >= 2, "fixture must want multi-rank fabrics");

        let sched = plan_concurrent(&comps, 4 * per_fabric, 0, 1, &m).unwrap();
        let mut seen: Vec<JobTag> = sched
            .waves
            .iter()
            .flat_map(|w| w.entries.iter().map(|e| e.tag))
            .collect();
        let flat = seen.clone();
        seen.sort();
        let want: Vec<JobTag> = comps.iter().map(|c| c.tag).collect();
        assert_eq!(seen, want, "every (job, component) scheduled exactly once");
        // All-ties LPT: entries come out in tag order across the waves.
        assert_eq!(flat, want, "tie-break must be job-major tag order");
        // Four fabrics fit per wave, so the first wave mixes jobs.
        assert!(
            sched.waves[0].entries.iter().map(|e| e.tag.job).collect::<Vec<_>>().windows(2).any(
                |w| w[0] != w[1]
            ),
            "first wave must mix fabrics from different jobs"
        );
        for w in &sched.waves {
            assert!(w.ranks() <= 4 * per_fabric);
        }
    }

    /// The memory budget splits waves the rank budget alone would pack:
    /// every wave's footprint sum stays within the budget, coverage is
    /// unchanged, and the peak resident words drop to at most the
    /// budget while the unbounded schedule's peak exceeds it.
    #[test]
    fn mem_budget_splits_waves_and_bounds_the_peak() {
        let comps = shapes(&[8_000.0, 8_000.0, 8_000.0, 8_000.0]);
        let m = machine();
        let per = comps[0].mem.words();
        assert!(per > 0);

        let unbounded = plan_concurrent(&comps, 64, 0, 1, &m).unwrap();
        assert!(unbounded.waves.iter().any(|w| w.entries.len() >= 2));
        assert!(unbounded.peak_mem_words() > per, "unbounded packs ≥ 2 footprints per wave");

        // Tight: exactly one component's footprint fits at a time.
        let tight = plan_concurrent(&comps, 64, per, 1, &m).unwrap();
        assert!(tight.waves.iter().all(|w| w.entries.len() == 1));
        assert_eq!(tight.peak_mem_words(), per);
        assert_eq!(tight.components(), comps.len(), "memory budget must not drop work");
        for w in &tight.waves {
            assert!(w.mem_words() <= tight.mem_budget);
        }
        assert!(tight.peak_mem_words() < unbounded.peak_mem_words());

        // Two footprints fit: waves pair up, the peak is bounded by the
        // budget, and the makespan sits between the two extremes.
        let pair = plan_concurrent(&comps, 64, 2 * per, 1, &m).unwrap();
        assert!(pair.waves.iter().all(|w| w.entries.len() <= 2));
        assert!(pair.peak_mem_words() <= 2 * per);
        assert!(pair.makespan() <= tight.makespan() + 1e-15);

        // Schedules only re-shape: plans and their modeled times are
        // untouched by the memory budget (rule 7 at the planning layer).
        let mut a: Vec<_> = tight.waves.iter().flat_map(|w| w.entries.clone()).collect();
        let mut b: Vec<_> = unbounded.waves.iter().flat_map(|w| w.entries.clone()).collect();
        a.sort_by_key(|e| e.tag);
        b.sort_by_key(|e| e.tag);
        assert_eq!(a, b, "memory budget must not change any plan");
    }

    /// A single component larger than a nonzero memory budget is a
    /// clean error naming the task — never a panic, never an overrun.
    #[test]
    fn oversized_component_is_a_clean_error() {
        let comps = shapes(&[8_000.0]);
        let m = machine();
        let need = comps[0].mem.words();
        let err = plan_concurrent(&comps, 64, need - 1, 1, &m).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("component"), "error must name the task: {msg}");
        assert!(msg.contains("memory budget"), "error must name the budget: {msg}");
        // At exactly the footprint it fits.
        assert!(plan_concurrent(&comps, 64, need, 1, &m).is_ok());
    }

    /// `JobTag::single` pins job 0, and the derived ordering is
    /// job-major (the sequential-reference launch order).
    #[test]
    fn job_tag_ordering_is_job_major() {
        assert_eq!(JobTag::single(3), JobTag { job: 0, component: 3 });
        let mut tags = vec![
            JobTag { job: 1, component: 0 },
            JobTag { job: 0, component: 2 },
            JobTag { job: 0, component: 1 },
            JobTag { job: 2, component: 0 },
        ];
        tags.sort();
        assert_eq!(
            tags,
            vec![
                JobTag { job: 0, component: 1 },
                JobTag { job: 0, component: 2 },
                JobTag { job: 1, component: 0 },
                JobTag { job: 2, component: 0 },
            ]
        );
    }
}
