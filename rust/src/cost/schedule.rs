//! Per-component fabric scheduling for screened solving.
//!
//! Screening splits one p×p problem into independent components; each
//! non-trivial component then deserves its *own* machine shape. This
//! module turns the Lemma 3.1–3.5 closed forms into that decision:
//! search power-of-two rank counts `P ≤ max_ranks` and every
//! fabric-runnable replication pair `(c_X, c_Ω)`, price each cell with
//! [`CostBreakdown::time_with_threads`](super::model::CostBreakdown),
//! and hand the component the cheapest `(P, c_X, c_Ω, variant)`. Small
//! components come back with `ranks == 1` — the model itself says the
//! communication would cost more than the parallelism buys, so they run
//! on the single-node path.

use crate::concord::Variant;
use crate::simnet::MachineParams;

use super::model::{CostBreakdown, ProblemShape, ReplicationChoice};
use super::optimizer::evaluate;

/// The fabric one screened component is assigned. `ranks == 1` means
/// the single-node path (no fabric is spun up at all).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FabricPlan {
    pub ranks: usize,
    pub c_x: usize,
    pub c_omega: usize,
    pub variant: Variant,
    /// Lemma 3.5 modeled time of this cell (flops at `threads` workers
    /// per rank; comm terms zero when `ranks == 1`).
    pub modeled_time: f64,
}

impl FabricPlan {
    /// The trivial single-node plan (used for components below the
    /// caller's cutoff, where no model evaluation is needed).
    pub fn single_node(variant: Variant) -> Self {
        FabricPlan { ranks: 1, c_x: 1, c_omega: 1, variant, modeled_time: 0.0 }
    }
}

/// True when the 1.5D rank programs can actually *run* this cell: every
/// rotation needs `c_F | T_R` (see `dist::rotate_parts`). Both variants
/// pair the grids as `(c_X, c_Ω)` and `(c_Ω, c_X)`; Cov's one-time gram
/// step additionally rotates the Xᵀ slabs against the X grid itself,
/// pairing `(c_X, c_X)` — i.e. requiring `c_X² ≤ P` for powers of two.
pub fn runnable_on_fabric(p_ranks: usize, c_x: usize, c_omega: usize, variant: Variant) -> bool {
    let rep = ReplicationChoice { p_procs: p_ranks, c_x, c_omega };
    if !rep.valid() {
        return false;
    }
    let pair_ok = |c_r: usize, c_f: usize| (p_ranks / c_r) % c_f == 0;
    let both = pair_ok(c_x, c_omega) && pair_ok(c_omega, c_x);
    match variant {
        Variant::Obs => both,
        // Auto is priced per concrete variant by the planner; treat it
        // conservatively so the cell is runnable whichever side wins.
        Variant::Cov | Variant::Auto => both && pair_ok(c_x, c_x),
    }
}

/// Choose the fabric for one screened component of shape `shape`
/// (`shape.p` is the component size): search power-of-two rank counts
/// up to `min(max_ranks, size)` (so no team is ever empty) and all
/// runnable replication pairs, minimizing modeled time under `threads`
/// node-local workers. Ties prefer fewer ranks, then lower replication.
pub fn plan_component(
    shape: &ProblemShape,
    max_ranks: usize,
    threads: usize,
    machine: &MachineParams,
    variant: Variant,
) -> FabricPlan {
    let variants: &[Variant] = match variant {
        Variant::Auto => &[Variant::Cov, Variant::Obs],
        Variant::Cov => &[Variant::Cov],
        Variant::Obs => &[Variant::Obs],
    };
    let size = (shape.p as usize).max(1);
    let threads = threads.max(1);
    let mut best: Option<FabricPlan> = None;
    let mut p_ranks = 1usize;
    while p_ranks <= max_ranks.max(1) && p_ranks <= size {
        let mut c_x = 1usize;
        while c_x <= p_ranks {
            let mut c_o = 1usize;
            while c_x * c_o <= p_ranks {
                for &v in variants {
                    if runnable_on_fabric(p_ranks, c_x, c_o, v) {
                        let rep = ReplicationChoice { p_procs: p_ranks, c_x, c_omega: c_o };
                        let time = price(&evaluate(shape, &rep, v), p_ranks, threads, machine);
                        if best.map(|b| time < b.modeled_time).unwrap_or(true) {
                            best = Some(FabricPlan {
                                ranks: p_ranks,
                                c_x,
                                c_omega: c_o,
                                variant: v,
                                modeled_time: time,
                            });
                        }
                    }
                }
                c_o *= 2;
            }
            c_x *= 2;
        }
        p_ranks *= 2;
    }
    best.expect("P = 1, c_X = c_Ω = 1 is always runnable")
}

/// Price one cell. At P = 1 nothing is sent — the closed forms'
/// residual L/W terms are rotation bookkeeping that degenerates to
/// self-sends — so only the flop terms count (priced at the same
/// installed-tile effective γ_dense as the fabric cells, so the
/// blocked-kernel cache-reuse term never biases the P = 1 decision).
fn price(cost: &CostBreakdown, p_ranks: usize, threads: usize, machine: &MachineParams) -> f64 {
    if p_ranks == 1 {
        let gamma_eff = machine.gamma_dense
            + crate::linalg::tile::current().gemm_words_per_flop() * machine.beta_mem;
        let flop_time = cost.flops_dense * gamma_eff + cost.flops_sparse * machine.gamma_sparse;
        flop_time / threads as f64
    } else {
        cost.time_with_threads(machine, p_ranks, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Edison with β_mem zeroed: plan comparisons across separate calls
    /// must not depend on the process-global tile shape (other tests
    /// install tiles concurrently).
    fn machine() -> MachineParams {
        MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() }
    }

    /// A tiny component: any communication dwarfs its flops, so the
    /// planner must route it to the single-node path.
    #[test]
    fn tiny_component_goes_single_node() {
        let shape = ProblemShape { p: 8.0, n: 100.0, s: 40.0, t: 10.0, d: 3.0 };
        let plan = plan_component(&shape, 64, 1, &machine(), Variant::Auto);
        assert_eq!(plan.ranks, 1);
        assert_eq!((plan.c_x, plan.c_omega), (1, 1));
    }

    /// A massive component is flop-bound: the planner should spend the
    /// whole rank budget on it.
    #[test]
    fn huge_component_takes_the_full_budget() {
        let shape = ProblemShape { p: 40_000.0, n: 100.0, s: 40.0, t: 10.0, d: 10.0 };
        let plan = plan_component(&shape, 64, 1, &machine(), Variant::Obs);
        assert_eq!(plan.ranks, 64);
        assert!(runnable_on_fabric(plan.ranks, plan.c_x, plan.c_omega, plan.variant));
    }

    /// The rank budget is never exceeded, and fabrics never outnumber
    /// the component's columns.
    #[test]
    fn plans_respect_budget_and_size() {
        let m = machine();
        for (p, max_ranks) in [(3.0, 64usize), (100.0, 8), (5_000.0, 16)] {
            let shape = ProblemShape { p, n: 50.0, s: 30.0, t: 8.0, d: 5.0 };
            let plan = plan_component(&shape, max_ranks, 4, &m, Variant::Auto);
            assert!(plan.ranks <= max_ranks);
            assert!(plan.ranks <= p as usize);
            assert!(plan.c_x * plan.c_omega <= plan.ranks);
            assert!(runnable_on_fabric(plan.ranks, plan.c_x, plan.c_omega, plan.variant));
            assert!(plan.modeled_time.is_finite());
        }
    }

    /// Cov plans honour the gram step's extra c_X² ≤ P constraint that
    /// plain `ReplicationChoice::valid` does not know about.
    #[test]
    fn runnable_enforces_cov_gram_constraint() {
        assert!(!runnable_on_fabric(8, 4, 2, Variant::Cov));
        assert!(runnable_on_fabric(8, 4, 2, Variant::Obs));
        assert!(runnable_on_fabric(16, 4, 2, Variant::Cov));
        assert!(!runnable_on_fabric(8, 4, 4, Variant::Obs), "c_X·c_Ω > P");
        assert!(runnable_on_fabric(1, 1, 1, Variant::Auto));
    }

    /// More node-local threads deflate the flop terms, so the threaded
    /// plan's modeled time can only improve.
    #[test]
    fn threads_never_hurt_the_plan() {
        let shape = ProblemShape { p: 2_000.0, n: 100.0, s: 40.0, t: 10.0, d: 10.0 };
        let m = machine();
        let t1 = plan_component(&shape, 32, 1, &m, Variant::Obs);
        let t8 = plan_component(&shape, 32, 8, &m, Variant::Obs);
        assert!(t8.modeled_time <= t1.modeled_time);
    }
}
