//! Replication optimizer: choose (c_X, c_Ω) minimizing modeled time
//! subject to c_X·c_Ω ≤ P and the per-process memory budget — the
//! decision Figure 3 makes empirically (its best cell, c_X=8, c_Ω=16,
//! is a 5× speedup over the non-communication-avoiding c_X=c_Ω=1).

use crate::concord::Variant;
use crate::simnet::MachineParams;

use super::model::{cov_cost, obs_cost, CostBreakdown, ProblemShape, ReplicationChoice};

/// Outcome of the grid search.
#[derive(Debug, Clone, Copy)]
pub struct OptimizerResult {
    pub choice: ReplicationChoice,
    pub variant: Variant,
    pub time: f64,
    pub cost: CostBreakdown,
}

/// Evaluate one (variant, replication) cell.
pub fn evaluate(
    shape: &ProblemShape,
    rep: &ReplicationChoice,
    variant: Variant,
) -> CostBreakdown {
    match variant {
        Variant::Cov => cov_cost(shape, rep),
        Variant::Obs => obs_cost(shape, rep),
        Variant::Auto => {
            if super::model::cov_is_cheaper_flops(shape) {
                cov_cost(shape, rep)
            } else {
                obs_cost(shape, rep)
            }
        }
    }
}

/// Search all power-of-two (c_X, c_Ω) with c_X·c_Ω ≤ P, under a memory
/// budget (words per process; `f64::INFINITY` to ignore). When
/// `variant` is [`Variant::Auto`], both variants are searched and the
/// best overall returned.
pub fn optimize_replication(
    shape: &ProblemShape,
    p_procs: usize,
    variant: Variant,
    machine: &MachineParams,
    memory_budget_words: f64,
) -> Option<OptimizerResult> {
    optimize_replication_threaded(shape, p_procs, variant, machine, memory_budget_words, 1)
}

/// [`optimize_replication`] pricing each cell with `threads` intra-node
/// workers (Lemma 3.5 with flops/t). More threads deflate the flop
/// terms, so the optimum drifts toward the communication-optimal corner
/// — replication pays off sooner on strongly-threaded nodes.
pub fn optimize_replication_threaded(
    shape: &ProblemShape,
    p_procs: usize,
    variant: Variant,
    machine: &MachineParams,
    memory_budget_words: f64,
    threads: usize,
) -> Option<OptimizerResult> {
    let variants: &[Variant] = match variant {
        Variant::Auto => &[Variant::Cov, Variant::Obs],
        Variant::Cov => &[Variant::Cov],
        Variant::Obs => &[Variant::Obs],
    };
    let mut best: Option<OptimizerResult> = None;
    let mut c_x = 1;
    while c_x <= p_procs {
        let mut c_o = 1;
        while c_x * c_o <= p_procs {
            let rep = ReplicationChoice { p_procs, c_x, c_omega: c_o };
            if rep.valid() {
                for &v in variants {
                    let cost = evaluate(shape, &rep, v);
                    if cost.memory_words <= memory_budget_words {
                        let time = cost.time_with_threads(machine, p_procs, threads);
                        if best.map(|b| time < b.time).unwrap_or(true) {
                            best = Some(OptimizerResult { choice: rep, variant: v, time, cost });
                        }
                    }
                }
            }
            c_o *= 2;
        }
        c_x *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Edison with β_mem zeroed: these tests compare prices across
    /// separate calls, so they must not depend on the process-global
    /// tile shape (other tests install tiles concurrently).
    fn machine() -> MachineParams {
        MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() }
    }

    fn shape() -> ProblemShape {
        // Fig. 3 regime: chain graph, p = 40k, n = 100.
        ProblemShape { p: 40_000.0, n: 100.0, s: 37.0, t: 10.0, d: 3.0 }
    }

    #[test]
    fn optimizer_beats_no_replication() {
        let m = machine();
        let s = shape();
        let p = 512;
        let best = optimize_replication(&s, p, Variant::Obs, &m, f64::INFINITY).unwrap();
        let naive = obs_cost(&s, &ReplicationChoice { p_procs: p, c_x: 1, c_omega: 1 })
            .time(&m, p);
        assert!(best.time < naive, "replication must win: {} !< {naive}", best.time);
        // Fig. 3 found ~5x on Edison; the modeled machine should show a
        // clearly super-unit speedup too.
        assert!(naive / best.time > 1.5, "speedup {}", naive / best.time);
        assert!(best.choice.c_x * best.choice.c_omega > 1);
    }

    #[test]
    fn memory_budget_constrains_choice() {
        let m = machine();
        let s = shape();
        let unconstrained =
            optimize_replication(&s, 256, Variant::Obs, &m, f64::INFINITY).unwrap();
        // A budget just above the c=1 requirement forces low replication.
        let min_mem = obs_cost(&s, &ReplicationChoice { p_procs: 256, c_x: 1, c_omega: 1 })
            .memory_words;
        let constrained =
            optimize_replication(&s, 256, Variant::Obs, &m, min_mem * 1.1).unwrap();
        assert!(constrained.cost.memory_words <= min_mem * 1.1);
        assert!(constrained.time >= unconstrained.time);
    }

    #[test]
    fn auto_variant_picks_cov_when_n_large_and_sparse() {
        let m = machine();
        // n = p/4 regime (Fig. 4c) with sparse iterates: Cov should win
        // even after the γ_sparse ≫ γ_dense penalty.
        let s = ProblemShape { p: 10_000.0, n: 2_500.0, s: 17.0, t: 10.0, d: 10.0 };
        let best = optimize_replication(&s, 64, Variant::Auto, &m, f64::INFINITY).unwrap();
        assert_eq!(best.variant, Variant::Cov);
    }

    #[test]
    fn gamma_sparse_delays_crossover_like_fig2() {
        // The paper observes the measured Cov/Obs crossover happens
        // *later* than Lemma 3.1 predicts because γ_sparse ≫ γ_dense.
        // Pick a shape where the flop rule says Cov but the priced model
        // says Obs: that is exactly the delayed-crossover region.
        let m = machine();
        let s = ProblemShape { p: 10_000.0, n: 2_500.0, s: 17.0, t: 10.0, d: 60.0 };
        assert!(super::super::model::cov_is_cheaper_flops(&s));
        let rep = ReplicationChoice { p_procs: 1, c_x: 1, c_omega: 1 };
        let tc = cov_cost(&s, &rep).time(&m, 1);
        let to = obs_cost(&s, &rep).time(&m, 1);
        assert!(to < tc, "γ_sparse should flip the winner here: {to} !< {tc}");
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let m = machine();
        assert!(optimize_replication(&shape(), 16, Variant::Obs, &m, 1.0).is_none());
    }

    #[test]
    fn threaded_optimum_is_no_slower_and_flop_share_shrinks() {
        let m = machine();
        let s = shape();
        let t1 = optimize_replication_threaded(&s, 256, Variant::Obs, &m, f64::INFINITY, 1)
            .unwrap();
        let t24 = optimize_replication_threaded(&s, 256, Variant::Obs, &m, f64::INFINITY, 24)
            .unwrap();
        // Same search space with strictly smaller cell times.
        assert!(t24.time < t1.time);
        // The threaded optimum's priced time must match re-pricing its
        // own cell (internal consistency).
        let repriced = evaluate(&s, &t24.choice, t24.variant).time_with_threads(&m, 256, 24);
        assert!((repriced - t24.time).abs() < 1e-12);
    }
}
