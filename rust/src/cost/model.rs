//! Lemmas 3.1–3.5: flop, latency, bandwidth, memory and total-time
//! closed forms for the Cov and Obs variants.
//!
//! Since the kernel layer went cache-blocked, the Lemma 3.5 pricing
//! carries a **cache-reuse term**: dense flops cost
//! `γ_dense + w(tile)·β_mem` seconds each, where `w(tile)` is the
//! blocked kernel's modeled slow-memory words per flop
//! ([`TileConfig::gemm_words_per_flop`]) and β_mem the node-local
//! per-word cost ([`MachineParams::beta_mem`]). At the default tile the
//! term is ~2% of γ_dense (the packed kernel runs near peak); pricing
//! the naive kernel's ½ word/flop ([`TileConfig::NAIVE_WORDS_PER_FLOP`])
//! triples the effective γ — which is why `cost::schedule` and the
//! optimizer consistently see the blocked kernel's machine, not the
//! naive one, when they trade flops against communication.

use crate::linalg::tile::{self, TileConfig};
use crate::simnet::MachineParams;

/// Problem characteristics entering the cost model (paper §3).
#[derive(Debug, Clone, Copy)]
pub struct ProblemShape {
    /// Dimensions p (variables) and n (samples).
    pub p: f64,
    pub n: f64,
    /// s: proximal gradient iterations.
    pub s: f64,
    /// t: mean line-search iterations per proximal iteration.
    pub t: f64,
    /// d: mean nonzeros per row of the iterates.
    pub d: f64,
}

/// A replication configuration on P processes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicationChoice {
    pub p_procs: usize,
    pub c_x: usize,
    pub c_omega: usize,
}

impl ReplicationChoice {
    /// Q = max(P/c_X², P/c_Ω²) (Lemmas 3.2/3.4). At heavy replication
    /// the group degenerates to a single partner; clamp at 1.
    pub fn q(&self) -> f64 {
        let p = self.p_procs as f64;
        let q1 = p / (self.c_x * self.c_x) as f64;
        let q2 = p / (self.c_omega * self.c_omega) as f64;
        q1.max(q2).max(1.0)
    }

    pub fn valid(&self) -> bool {
        self.c_x * self.c_omega <= self.p_procs
            && self.p_procs % (self.c_x * self.c_omega) == 0
    }
}

/// Itemized cost of one variant under one configuration.
#[derive(Debug, Clone, Copy)]
pub struct CostBreakdown {
    /// Total flops F (dense and sparse parts split out).
    pub flops_dense: f64,
    pub flops_sparse: f64,
    /// Latency count L (messages along the critical path).
    pub messages: f64,
    /// Bandwidth count W (words along the critical path).
    pub words: f64,
    /// Memory per process, in words (M_Cov / M_Obs).
    pub memory_words: f64,
}

impl CostBreakdown {
    /// Lemma 3.5: T = F·γ + L·α + W·β, with the dense/sparse flop split
    /// the paper's Fig. 2 discussion calls out (γ_sparse ≫ γ_dense).
    /// Flops are divided by P (perfectly parallelized work — the
    /// lemma counts totals).
    pub fn time(&self, m: &MachineParams, p_procs: usize) -> f64 {
        self.time_with_threads(m, p_procs, 1)
    }

    /// Lemma 3.5 with intra-node threading: the paper's model of a node
    /// is "threaded MKL on t cores" (§4 uses t = 24), so the flop terms
    /// divide by P·t while the α/β communication terms are untouched —
    /// threading moves the Lemma-predicted Cov/Obs and replication
    /// crossovers exactly the way adding cores did on Edison. Dense
    /// flops are priced at the process-wide installed tile shape
    /// ([`tile::current`]); see [`CostBreakdown::time_with_tile`].
    pub fn time_with_threads(&self, m: &MachineParams, p_procs: usize, threads: usize) -> f64 {
        self.time_with_tile(m, p_procs, threads, &tile::current())
    }

    /// [`CostBreakdown::time_with_threads`] at an explicit tile shape —
    /// Lemma 3.5 plus the cache-reuse term:
    ///
    /// ```text
    /// T = F_dense·(γ_dense + w(tile)·β_mem)/(P·t)
    ///   + F_sparse·γ_sparse/(P·t) + L·α + W·β
    /// ```
    ///
    /// The whole per-flop cost (reuse term included) divides by P·t:
    /// intra-node threads share the node's memory streams in this model
    /// just as they share its FPUs. `β_mem = 0` recovers the plain
    /// Lemma 3.5 form exactly.
    pub fn time_with_tile(
        &self,
        m: &MachineParams,
        p_procs: usize,
        threads: usize,
        tile: &TileConfig,
    ) -> f64 {
        let div = (p_procs * threads.max(1)) as f64;
        let gamma_eff = m.gamma_dense + tile.gemm_words_per_flop() * m.beta_mem;
        self.flops_dense / div * gamma_eff
            + self.flops_sparse / div * m.gamma_sparse
            + self.messages * m.alpha
            + self.words * m.beta
    }

    /// What the same cell would cost if the local GEMM were the naive
    /// unblocked kernel (½ word of memory traffic per flop instead of
    /// `w(tile)`). The blocked-vs-naive pricing gap this opens against
    /// [`CostBreakdown::time_with_tile`] is the modeled single-node win
    /// the `perf_hotpath` bench measures for real.
    pub fn time_naive_kernel(&self, m: &MachineParams, p_procs: usize, threads: usize) -> f64 {
        let div = (p_procs * threads.max(1)) as f64;
        let gamma_eff = m.gamma_dense + TileConfig::NAIVE_WORDS_PER_FLOP * m.beta_mem;
        self.flops_dense / div * gamma_eff
            + self.flops_sparse / div * m.gamma_sparse
            + self.messages * m.alpha
            + self.words * m.beta
    }

    /// Communication-only part (L·α + W·β) — invariant in `threads`.
    pub fn comm_time(&self, m: &MachineParams) -> f64 {
        self.messages * m.alpha + self.words * m.beta
    }
}

/// Lemma 3.1 (flops) + Lemma 3.4 (communication) + §3 (memory) for Cov:
///
/// ```text
/// F_Cov = 2np² + 2dp²(st+1)
/// L_Cov = P/c_X² + st·P/(c_X·c_Ω) + log₂(Q)
/// W_Cov = np/c_X + st·dp/c_X + p²·(c_X c_Ω/P)·Q·log₂(Q)
/// M_Cov = c_Ω·dp + 3·c_X·p²  (words)
/// ```
pub fn cov_cost(shape: &ProblemShape, rep: &ReplicationChoice) -> CostBreakdown {
    let ProblemShape { p, n, s, t, d } = *shape;
    let pp = rep.p_procs as f64;
    let (cx, co) = (rep.c_x as f64, rep.c_omega as f64);
    let q = rep.q();
    let lq = q.log2().max(0.0);
    CostBreakdown {
        flops_dense: 2.0 * n * p * p,
        flops_sparse: 2.0 * d * p * p * (s * t + 1.0),
        messages: pp / (cx * cx) + s * t * pp / (cx * co) + lq,
        words: n * p / cx + s * t * d * p / cx + p * p * (cx * co / pp) * q * lq,
        memory_words: co * d * p + 3.0 * cx * p * p,
    }
}

/// Lemma 3.1 + 3.4 + §3 for Obs:
///
/// ```text
/// F_Obs = 2np²s + 2dnp(st+1)
/// L_Obs = s(t+1)·P/(c_Ω·c_X) + log₂(Q)
/// W_Obs = s(t+1)·np/c_Ω + p²·(c_X c_Ω/P)·Q·log₂(Q)
/// M_Obs = 2c_X·np + c_Ω(dp + np + 2p²)  (words)
/// ```
pub fn obs_cost(shape: &ProblemShape, rep: &ReplicationChoice) -> CostBreakdown {
    let ProblemShape { p, n, s, t, d } = *shape;
    let pp = rep.p_procs as f64;
    let (cx, co) = (rep.c_x as f64, rep.c_omega as f64);
    let q = rep.q();
    let lq = q.log2().max(0.0);
    CostBreakdown {
        flops_dense: 2.0 * n * p * p * s,
        flops_sparse: 2.0 * d * n * p * (s * t + 1.0),
        messages: s * (t + 1.0) * pp / (co * cx) + lq,
        words: s * (t + 1.0) * n * p / co + p * p * (cx * co / pp) * q * lq,
        memory_words: 2.0 * cx * n * p + co * (d * p + n * p + 2.0 * p * p),
    }
}

/// Lemma 3.1's crossover: Cov is cheaper in flops iff
/// d/p < n/(p−n) · 1/t.
pub fn cov_is_cheaper_flops(shape: &ProblemShape) -> bool {
    if shape.n >= shape.p {
        return true;
    }
    shape.d / shape.p < shape.n / (shape.p - shape.n) / shape.t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> ProblemShape {
        ProblemShape { p: 40_000.0, n: 100.0, s: 40.0, t: 10.0, d: 10.0 }
    }

    fn rep(p: usize, cx: usize, co: usize) -> ReplicationChoice {
        ReplicationChoice { p_procs: p, c_x: cx, c_omega: co }
    }

    /// Edison with β_mem zeroed: exact-relation tests below must not
    /// depend on the process-global tile shape (other tests install
    /// tiles concurrently), and β_mem = 0 makes every tile price alike.
    fn machine_no_mem() -> MachineParams {
        MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() }
    }

    #[test]
    fn lemma31_exact_flop_forms() {
        let s = shape();
        let c = cov_cost(&s, &rep(32, 1, 1));
        assert_eq!(c.flops_dense, 2.0 * s.n * s.p * s.p);
        assert_eq!(c.flops_sparse, 2.0 * s.d * s.p * s.p * (s.s * s.t + 1.0));
        let o = obs_cost(&s, &rep(32, 1, 1));
        assert_eq!(o.flops_dense, 2.0 * s.n * s.p * s.p * s.s);
        assert_eq!(o.flops_sparse, 2.0 * s.d * s.n * s.p * (s.s * s.t + 1.0));
    }

    #[test]
    fn lemma31_crossover_consistent_with_flop_totals() {
        // On both sides of the crossover, the rule must agree with the
        // actual relaxed flop comparison direction.
        let mut s = shape();
        s.d = 1.0; // very sparse: Cov wins
        assert!(cov_is_cheaper_flops(&s));
        let c = cov_cost(&s, &rep(1, 1, 1));
        let o = obs_cost(&s, &rep(1, 1, 1));
        assert!(
            c.flops_dense + c.flops_sparse < o.flops_dense + o.flops_sparse
        );

        s.d = 4000.0; // dense iterates: Obs wins
        assert!(!cov_is_cheaper_flops(&s));
        let c = cov_cost(&s, &rep(1, 1, 1));
        let o = obs_cost(&s, &rep(1, 1, 1));
        assert!(c.flops_dense + c.flops_sparse > o.flops_dense + o.flops_sparse);
    }

    #[test]
    fn replication_cuts_latency_and_bandwidth_lemma34() {
        let s = shape();
        let base = obs_cost(&s, &rep(512, 1, 1));
        let repl = obs_cost(&s, &rep(512, 8, 16));
        // L scales by 1/(c_X·c_Ω) in the dominant term, W by 1/c_Ω.
        assert!(repl.messages < base.messages / 64.0);
        assert!(repl.words < base.words);
    }

    #[test]
    fn obs_words_formula_spotcheck() {
        let s = ProblemShape { p: 100.0, n: 10.0, s: 2.0, t: 3.0, d: 5.0 };
        let r = rep(16, 2, 2);
        let o = obs_cost(&s, &r);
        let q: f64 = 4.0;
        let want = 2.0 * 4.0 * 10.0 * 100.0 / 2.0
            + 100.0 * 100.0 * (4.0 / 16.0) * q * q.log2();
        assert!((o.words - want).abs() < 1e-9);
    }

    #[test]
    fn memory_grows_with_replication() {
        let s = shape();
        let m1 = cov_cost(&s, &rep(64, 1, 1)).memory_words;
        let m2 = cov_cost(&s, &rep(64, 4, 1)).memory_words;
        assert!(m2 > m1);
    }

    #[test]
    fn time_is_monotone_in_machine_params() {
        let s = shape();
        let c = cov_cost(&s, &rep(16, 2, 2));
        let m1 = machine_no_mem();
        let mut m2 = m1;
        m2.alpha *= 10.0;
        assert!(c.time(&m2, 16) > c.time(&m1, 16));
    }

    #[test]
    fn cache_reuse_term_prices_blocked_below_naive() {
        let s = shape();
        let c = cov_cost(&s, &rep(16, 2, 2));
        let m = MachineParams::edison_like();
        let tile = TileConfig::DEFAULT;
        let blocked = c.time_with_tile(&m, 16, 1, &tile);
        let naive = c.time_naive_kernel(&m, 16, 1);
        assert!(blocked < naive, "blocked {blocked} !< naive {naive}");
        // The gap is exactly the traffic difference on the dense flops.
        let want_gap = c.flops_dense / 16.0
            * (TileConfig::NAIVE_WORDS_PER_FLOP - tile.gemm_words_per_flop())
            * m.beta_mem;
        assert!((naive - blocked - want_gap).abs() / want_gap < 1e-12);
        // β_mem = 0 recovers the plain Lemma 3.5 pricing: every tile
        // shape (and the naive kernel) then costs the same.
        let m0 = machine_no_mem();
        let t0 = c.time_with_tile(&m0, 16, 1, &TileConfig::new(1, 1, 1));
        assert_eq!(t0, c.time_with_tile(&m0, 16, 1, &tile));
        assert_eq!(t0, c.time_naive_kernel(&m0, 16, 1));
        // Smaller tiles → less reuse → never cheaper.
        assert!(
            c.time_with_tile(&m, 16, 1, &TileConfig::new(8, 8, 8))
                >= c.time_with_tile(&m, 16, 1, &tile)
        );
    }

    #[test]
    fn q_clamps_at_one() {
        assert_eq!(rep(4, 4, 1).q(), 4.0);
        assert_eq!(rep(4, 2, 2).q(), 1.0);
    }

    #[test]
    fn threads_scale_flop_time_only() {
        let s = shape();
        let r = rep(64, 2, 2);
        let m = MachineParams::edison_like();
        let c = cov_cost(&s, &r);
        // Explicit tile: the relation below needs both prices computed
        // at one fixed shape, immune to concurrent tile installs.
        let tile = TileConfig::DEFAULT;
        let t1 = c.time_with_tile(&m, 64, 1, &tile);
        let t24 = c.time_with_tile(&m, 64, 24, &tile);
        let comm = c.comm_time(&m);
        // Exactly the flop part (cache-reuse term included) shrinks by
        // 24×; communication is fixed.
        assert!((t1 - comm - 24.0 * (t24 - comm)).abs() / t1 < 1e-12);
        let m0 = machine_no_mem();
        assert_eq!(c.time(&m0, 64), c.time_with_tile(&m0, 64, 1, &tile));
    }

    #[test]
    fn threads_move_the_priced_crossover() {
        // A shape in the delayed-crossover region: flop-dominated at
        // t = 1 (γ_sparse makes Obs win), communication-dominated at
        // large t. Intra-node threading shrinks only the flop terms, so
        // the Cov-vs-Obs *priced* winner can flip with t — the Lemma
        // 3.5 behaviour the paper's Fig. 2 discussion describes.
        let m = machine_no_mem();
        let s = ProblemShape { p: 10_000.0, n: 2_500.0, s: 17.0, t: 10.0, d: 60.0 };
        let r = rep(1, 1, 1);
        let (c, o) = (cov_cost(&s, &r), obs_cost(&s, &r));
        assert!(o.time_with_threads(&m, 1, 1) < c.time_with_threads(&m, 1, 1));
        let ratio_t1 = c.time_with_threads(&m, 1, 1) / o.time_with_threads(&m, 1, 1);
        let ratio_t64 = c.time_with_threads(&m, 1, 64) / o.time_with_threads(&m, 1, 64);
        // With flops deflated 64×, Cov's γ_sparse handicap fades: the
        // ratio must move toward (or past) parity.
        assert!(
            ratio_t64 < ratio_t1,
            "threading must move the crossover: {ratio_t64} !< {ratio_t1}"
        );
    }
}
