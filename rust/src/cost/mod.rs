//! Analytic cost model: Lemmas 3.1–3.5 in executable form, plus the
//! replication optimizer.
//!
//! The paper prices a run as `T = F·γ + L·α + W·β` with per-variant
//! closed forms (Lemma 3.5). This module implements those forms exactly
//! — they drive the runtime curves of Figures 2–4 and the
//! extrapolations to the paper's (p up to 1.28M, P up to 2048 processes)
//! scales — and an optimizer that searches the (c_X, c_Ω) grid subject
//! to c_X·c_Ω ≤ P and the memory bounds M_Cov/M_Obs (paper §3, "Space
//! complexity").
//!
//! The measured counters from [`crate::simnet`] cross-check these
//! formulas in `rust/tests/lemma_counts.rs`.

pub mod model;
pub mod optimizer;
pub mod schedule;

pub use model::{CostBreakdown, ProblemShape, ReplicationChoice};
pub use optimizer::{optimize_replication, OptimizerResult};
pub use schedule::{plan_component, FabricPlan, MemFootprint, PackItem};

pub use crate::simnet::cost::{CostModel, MachineParams};
