//! The `hpconcord` launcher: the L3 leader entrypoint.
//!
//! Subcommands (see `hpconcord help`): `solve` (single problem, single
//! node or simulated distributed), `sweep` (tuning-grid coordinator),
//! `serve` (the long-running multi-tenant estimation service),
//! `client` (submit a job to a running server), `cost` (analytic
//! Lemma 3.1–3.5 model + replication optimizer), `fmri` (the §5
//! synthetic-cortex pipeline), `engine` (PJRT artifact smoke runs).
//! Python never runs here — artifacts are pre-built by
//! `make artifacts`.
//!
//! `solve`, `sweep`, `client` and every served job all construct one
//! [`EstimationRequest`] and execute through its canonical entry
//! points, so the config-resolution prologue has a single owner and a
//! served result is byte-identical to the CLI's (determinism rule 9).

use anyhow::{anyhow, Result};

use hpconcord::cli::{Args, USAGE};
use hpconcord::concord::request::{kernel_lane, node_threads, parse_variant, tile_config};
use hpconcord::concord::{
    fit_distributed, fit_single_node, fit_with_screening, EstimationRequest, RequestKind,
    RequestOutcome, ScreenedDistFit, WorkloadSpec,
};
use hpconcord::config::Config;
use hpconcord::coordinator::{
    run_sweep, run_sweep_screened, select_by_density, GridSpec, ScreenedDistSweepOutcome,
    StabilityConfig, SweepResult,
};
use hpconcord::cost::ProblemShape;
use hpconcord::gen;
use hpconcord::io::{self, XDisk, XSource};
use hpconcord::linalg::{tile, Mat};
use hpconcord::metrics::support_metrics;
use hpconcord::rng::Rng;
use hpconcord::runtime::Engine;
use hpconcord::serve::{Client, ServeOptions, Server};
use hpconcord::simnet::MachineParams;
use hpconcord::util::Table;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let code = match args.subcommand() {
        Some("solve") => run(cmd_solve(&args)),
        Some("sweep") => run(cmd_sweep(&args)),
        Some("serve") => run(cmd_serve(&args)),
        Some("client") => run(cmd_client(&args)),
        Some("convert") => run(cmd_convert(&args)),
        Some("cost") => run(cmd_cost(&args)),
        Some("fmri") => run(cmd_fmri(&args)),
        Some("engine") => run(cmd_engine(&args)),
        Some("help") | None => {
            print!("{USAGE}");
            0
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Parse the --config file once per command (empty Config when absent).
fn load_config(args: &Args) -> Result<Config> {
    match args.str_or("config", "").as_str() {
        "" => Ok(Config::default()),
        path => Config::load(path),
    }
}

/// Validate `--mode` before any data is loaded (the fail-fast pattern
/// every subcommand follows: flag misuse errors before an expensive
/// problem generation or file read).
fn solve_mode(args: &Args) -> Result<String> {
    let mode = args.str_or("mode", "single");
    if mode != "single" && mode != "dist" {
        return Err(anyhow!("unknown --mode {mode:?} (single|dist)"));
    }
    Ok(mode)
}

/// `--x-file` replaces the in-core X on the screened distributed paths
/// only — every other mode reads X through code that has no
/// [`XSource`] seam — so using it elsewhere is a clean error rather
/// than a silently ignored flag.
fn validate_x_file_mode(x_file: Option<&str>, mode: &str, screen: bool) -> Result<()> {
    if x_file.is_some() && !(mode == "dist" && screen) {
        return Err(anyhow!(
            "--x-file applies to --mode dist with --screen only (the on-disk X backend \
             sits behind the screened distributed executor seam)"
        ));
    }
    Ok(())
}

/// Open and validate an HPCX x-file against the generated workload:
/// the generator still supplies the ground-truth omega0 the support
/// metrics read, so the file must describe the same n × p problem.
fn open_x_file(path: &str, problem: &gen::Problem) -> Result<XDisk> {
    let xd = XDisk::open(std::path::Path::new(path))?;
    let (n, p) = problem.x.shape();
    if (xd.rows(), xd.cols()) != (n, p) {
        return Err(anyhow!(
            "x-file {path} holds a {}×{} matrix but the workload is {n}×{p} \
             (write it with `convert` using the same workload options)",
            xd.rows(),
            xd.cols()
        ));
    }
    Ok(xd)
}

/// Write an estimate as whitespace-separated rows with full f64
/// round-trip precision (`--out-omega`): deterministic bytes
/// ([`io::format_omega`] — the same bytes the serve protocol returns),
/// so two runs that claim bit-identical results can be compared with
/// `cmp`.
fn write_omega(path: &str, omega: &Mat) -> Result<()> {
    std::fs::write(path, io::format_omega(omega))
        .map_err(|e| anyhow!("writing omega to {path}: {e}"))
}

/// Write grid results as CSV (`sweep --out-csv`): one row per (λ₁, λ₂)
/// point with the quantities offline model selection needs. The
/// `components` and `modeled_time_s` columns are filled when the sweep
/// mode produces them (screened sweeps know their decompositions; the
/// distributed sweep also bills per point) and left empty otherwise.
fn write_sweep_csv(
    path: &str,
    results: &[SweepResult],
    components: Option<&[usize]>,
    modeled: Option<&[f64]>,
) -> Result<()> {
    use std::fmt::Write as _;
    let mut text = String::from("lambda1,lambda2,density,iterations,components,modeled_time_s\n");
    for (k, r) in results.iter().enumerate() {
        let comps = components.map(|c| c[k].to_string()).unwrap_or_default();
        let time = modeled.map(|t| format!("{:e}", t[k])).unwrap_or_default();
        writeln!(
            text,
            "{},{},{},{},{comps},{time}",
            r.job.cfg.lambda1, r.job.cfg.lambda2, r.density, r.fit.iterations
        )
        .expect("string write");
    }
    std::fs::write(path, text).map_err(|e| anyhow!("writing sweep csv to {path}: {e}"))
}

/// Run a Solve request and unwrap its outcome variant.
fn solve_outcome(req: &EstimationRequest, x: XSource<'_>) -> Result<ScreenedDistFit> {
    match req.run(x)? {
        RequestOutcome::Solve(fit) => Ok(*fit),
        _ => Err(anyhow!("a Solve request must produce a Solve outcome")),
    }
}

/// Run a Sweep request and unwrap its outcome variant.
fn sweep_outcome(req: &EstimationRequest, x: XSource<'_>) -> Result<ScreenedDistSweepOutcome> {
    match req.run(x)? {
        RequestOutcome::Sweep(out) => Ok(out),
        _ => Err(anyhow!("a Sweep request must produce a Sweep outcome")),
    }
}

fn cmd_solve(args: &Args) -> Result<()> {
    // Fail-fast prologue: flags and config resolve into the request
    // before any workload is generated or file opened.
    let file_cfg = load_config(args)?;
    let mode = solve_mode(args)?;
    let screen = args.has("screen") || file_cfg.bool_or("solver.screen", false)?;
    let req = EstimationRequest::from_args(RequestKind::Solve, args, &file_cfg)?;
    validate_x_file_mode(req.x_file.as_deref(), &mode, screen)?;
    let problem = req.workload.generate()?;
    let cfg = req.cfg;
    let t0 = std::time::Instant::now();

    let (fit, cost_line) = match (mode.as_str(), screen) {
        ("single", true) => {
            let out = fit_with_screening(&problem.x, &cfg)?;
            println!(
                "screening: {} components (largest {}) at λ1={}",
                out.components, out.largest, cfg.lambda1
            );
            (out.fit, String::new())
        }
        ("single", false) => {
            let artifacts = args.str_or("artifacts", "artifacts");
            let fit = match Engine::load(&artifacts) {
                Ok(mut engine) if engine.has_trial(problem.x.cols()) => {
                    eprintln!("using PJRT artifact trial_p{}", problem.x.cols());
                    hpconcord::concord::single_node::fit_single_node_with_engine(
                        &problem.x, &cfg, &mut engine,
                    )?
                }
                _ => fit_single_node(&problem.x, &cfg)?,
            };
            (fit, String::new())
        }
        ("dist", true) => {
            // Determinism rule 8: the on-disk branch is the in-core
            // run's bit-exact twin — compare `--out-omega`s with cmp.
            let out = match &req.x_file {
                Some(path) => {
                    let xd = open_x_file(path, &problem)?;
                    solve_outcome(&req, XSource::OnDisk(&xd))?
                }
                None => solve_outcome(&req, XSource::InCore(&problem.x))?,
            };
            println!(
                "screening: {} components (largest {}) at λ1={}; \
                 screen pass comm {:.6}s",
                out.components, out.largest, cfg.lambda1, out.screen_cost.comm_time
            );
            let mut unmetered = 0usize;
            for sv in &out.solves {
                if sv.plan.ranks <= 1 {
                    unmetered += 1;
                    println!(
                        "  component p={:<6} → single-node path (unmetered)",
                        sv.indices.len()
                    );
                } else {
                    let wave = sv.wave.map(|w| format!("wave {w}")).unwrap_or_default();
                    println!(
                        "  component p={:<6} → P={} c_X={} c_Ω={} {:?}  \
                         modeled {:.4}s (comm {:.4}s)  {wave}",
                        sv.indices.len(),
                        sv.plan.ranks,
                        sv.plan.c_x,
                        sv.plan.c_omega,
                        sv.plan.variant,
                        sv.cost.time,
                        sv.cost.comm_time
                    );
                }
            }
            if !out.schedule.waves.is_empty() {
                let mem = match out.schedule.mem_budget {
                    0 => "unbounded memory".to_string(),
                    b => format!("memory budget {b} words"),
                };
                println!(
                    "schedule: {} wave(s) under rank budget {} ({mem}) — modeled \
                     makespan {:.4}s vs {:.4}s sequential; peak residency {} words",
                    out.schedule.waves.len(),
                    out.schedule.budget,
                    out.schedule.makespan(),
                    out.schedule.sequential_time(),
                    out.schedule.peak_mem_words()
                );
            }
            let s = out.cost;
            let seq = out.sequential_bill();
            let note = if unmetered > 0 {
                format!("  [{unmetered} single-node component(s) excluded]")
            } else {
                String::new()
            };
            let line = format!(
                "screened aggregate (concurrent critical path): modeled time {:.4}s \
                 (comm {:.4}s; sequential bill {:.4}s)  \
                 max/rank: {} msgs, {} words{note}",
                s.time, s.comm_time, seq.time, s.max_per_rank.messages, s.max_per_rank.words
            );
            (out.fit, line)
        }
        ("dist", false) => {
            let ranks = req.opts.total_ranks;
            let (c_x, c_o) = match req.opts.fixed {
                Some((_, c_x, c_o)) => (c_x, c_o),
                None => (1, 1),
            };
            let out = fit_distributed(&problem.x, &cfg, ranks, c_x, c_o, MachineParams::default());
            let s = out.cost;
            let line = format!(
                "variant {:?}  modeled time {:.4}s (comm {:.4}s)  max/rank: {} msgs, {} words",
                out.variant, s.time, s.comm_time, s.max_per_rank.messages, s.max_per_rank.words
            );
            (out.fit, line)
        }
        _ => unreachable!("solve_mode validated --mode"),
    };

    let wall = t0.elapsed().as_secs_f64();
    let m = support_metrics(&fit.omega, &problem.omega0, 1e-8);
    println!(
        "p={} n={} λ1={} λ2={}  iters={} (t̄={:.1})  d̄={:.1}  obj={:.6}  converged={}",
        problem.x.cols(),
        problem.x.rows(),
        cfg.lambda1,
        cfg.lambda2,
        fit.iterations,
        fit.mean_linesearch,
        fit.mean_row_nnz,
        fit.objective,
        fit.converged
    );
    println!(
        "support: PPV={:.2}%  FDR={:.2}%  recall={:.2}%   wallclock {:.3}s",
        100.0 * m.ppv,
        100.0 * m.fdr,
        100.0 * m.recall,
        wall
    );
    if !cost_line.is_empty() {
        println!("{cost_line}");
    }
    // The kernel-layer bill: which ISA lane and tile shape actually ran
    // (the resolved lane, not the `auto` the user typed — rule 10 says
    // neither can move a result bit, so this line is throughput only).
    println!(
        "kernel: {} lane, tile {}{}",
        cfg.kernel.resolve().as_str(),
        cfg.tile,
        if cfg.pin_cores { ", cores pinned" } else { "" }
    );
    let out_omega = args.str_or("out-omega", "");
    if !out_omega.is_empty() {
        write_omega(&out_omega, &fit.omega)?;
        println!("wrote omega to {out_omega}");
    }
    Ok(())
}

/// Validate the sweep's `--mode`/`--per-point` combination before any
/// data is loaded, so flag misuse fails fast with a clean message
/// instead of after an expensive problem generation or file read.
fn sweep_mode(args: &Args) -> Result<String> {
    let mode = args.str_or("mode", "single");
    if mode != "single" && mode != "dist" {
        return Err(anyhow!("unknown --mode {mode:?} (single|dist)"));
    }
    if args.has("per-point") && mode != "dist" {
        return Err(anyhow!(
            "--per-point applies to sweep --screen --mode dist only (it picks the \
             per-point reference schedule of the distributed sweep)"
        ));
    }
    Ok(mode)
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let mode = sweep_mode(args)?;
    let file_cfg = load_config(args)?;
    let grid = GridSpec {
        lambda1: args.f64_list_or("l1", &[0.2, 0.3, 0.45])?,
        lambda2: args.f64_list_or("l2", &[0.0, 0.1])?,
    };
    let per_point = args.has("per-point");
    let kind = RequestKind::Sweep { grid: grid.clone(), per_point };
    let req = EstimationRequest::from_args(kind, args, &file_cfg)?;
    let base = req.cfg;
    let workers = args.usize_or("workers", 4)?;
    let screen = args.has("screen") || file_cfg.bool_or("solver.screen", false)?;
    validate_x_file_mode(req.x_file.as_deref(), &mode, screen)?;
    let problem = req.workload.generate()?;
    // Per-point component counts and modeled times, when the sweep mode
    // produces them (threaded into the table and the --out-csv rows).
    let mut components_col: Option<Vec<usize>> = None;
    let mut modeled_col: Option<Vec<f64>> = None;
    let results = if mode == "dist" {
        if !screen {
            return Err(anyhow!(
                "sweep --mode dist requires --screen (the distributed sweep is the screened one)"
            ));
        }
        if args.has("workers") {
            eprintln!(
                "note: --workers applies to the single-node sweep; the dist sweep packs \
                 component fabrics into waves (parallelism comes from the shared schedule)"
            );
        }
        let out = match &req.x_file {
            Some(path) => {
                let xd = open_x_file(path, &problem)?;
                sweep_outcome(&req, XSource::OnDisk(&xd))?
            }
            None => sweep_outcome(&req, XSource::InCore(&problem.x))?,
        };
        let comps: Vec<String> = out.components.iter().map(|c| c.to_string()).collect();
        println!(
            "screened dist sweep ({}): components per point = [{}]",
            if per_point { "per-point" } else { "packed" },
            comps.join(", ")
        );
        if let [sched] = &out.schedules[..] {
            println!(
                "grid schedule: {} wave(s) under rank budget {} — modeled makespan \
                 {:.4}s vs {:.4}s sequential",
                sched.waves.len(),
                sched.budget,
                sched.makespan(),
                sched.sequential_time()
            );
        }
        println!(
            "grid bill: screening {:.4}s + waves {:.4}s = {:.4}s modeled \
             (comm {:.4}s; unpacked serial {:.4}s)",
            out.bill.screen.time,
            out.bill.waves.time,
            out.cost.time,
            out.cost.comm_time,
            out.bill.sequential().time
        );
        components_col = Some(out.components);
        modeled_col = Some(out.bill.per_job.iter().map(|c| c.time).collect());
        out.results
    } else if screen {
        let out = run_sweep_screened(&problem.x, &grid, &base, workers);
        let comps: Vec<String> = out.components_per_l1.iter().map(|c| c.to_string()).collect();
        println!("screened sweep: components per λ1 = [{}]", comps.join(", "));
        components_col =
            Some(out.results.iter().map(|r| out.components_per_l1[r.job.grid_pos.0]).collect());
        out.results
    } else {
        run_sweep(&problem.x, &grid, &base, workers).results
    };
    let mut table = Table::new(&["λ1", "λ2", "iters", "density%", "PPV%", "FDR%"]);
    for r in &results {
        let m = support_metrics(&r.fit.omega, &problem.omega0, 1e-8);
        table.row(vec![
            format!("{:.3}", r.job.cfg.lambda1),
            format!("{:.3}", r.job.cfg.lambda2),
            format!("{}", r.fit.iterations),
            format!("{:.2}", 100.0 * r.density),
            format!("{:.2}", 100.0 * m.ppv),
            format!("{:.2}", 100.0 * m.fdr),
        ]);
    }
    print!("{table}");
    let out_csv = args.str_or("out-csv", "");
    if !out_csv.is_empty() {
        write_sweep_csv(&out_csv, &results, components_col.as_deref(), modeled_col.as_deref())?;
        println!("wrote grid csv to {out_csv}");
    }
    let out_omega = args.str_or("out-omega", "");
    if !out_omega.is_empty() || args.has("select-density") {
        let target = args.f64_or("select-density", 0.1)?;
        let sel = select_by_density(&results, target)
            .ok_or_else(|| anyhow!("empty sweep: nothing to select"))?;
        println!(
            "selected λ1={} λ2={} (density {:.4} vs target {target})",
            sel.job.cfg.lambda1, sel.job.cfg.lambda2, sel.density
        );
        if !out_omega.is_empty() {
            write_omega(&out_omega, &sel.fit.omega)?;
            println!("wrote selected omega to {out_omega}");
        }
    }
    Ok(())
}

/// Resolve and validate the serve flags **before** binding or loading
/// anything (the fail-fast hoist `sweep_mode` established): the bind
/// address must look like host:port, and the global budgets parse as
/// integers. CLI flags win over the `[serve]` config section.
fn serve_options(args: &Args, cfg: &Config) -> Result<ServeOptions> {
    let addr = args.str_or("addr", cfg.str_or("serve.addr", "127.0.0.1:7878")?);
    if !addr.contains(':') {
        return Err(anyhow!("--addr must be host:port, got {addr:?}"));
    }
    Ok(ServeOptions {
        addr,
        ranks_budget: args.usize_or("ranks-budget", cfg.usize_or("serve.ranks_budget", 0)?)?,
        mem_budget: args.u64_or("mem-budget", cfg.u64_or("serve.mem_budget", 0)?)?,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let file_cfg = load_config(args)?;
    let opts = serve_options(args, &file_cfg)?;
    let server = Server::start(opts)?;
    // One parseable line for scripts (the CI smoke reads the port from
    // it), then serve until a client sends the `shutdown` op.
    println!("serving on {}", server.addr());
    server.join();
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let file_cfg = load_config(args)?;
    let addr = args.str_or("addr", file_cfg.str_or("serve.addr", "127.0.0.1:7878")?);
    if !addr.contains(':') {
        return Err(anyhow!("--addr must be host:port, got {addr:?}"));
    }
    if args.has("shutdown") {
        Client::connect(&addr)?.shutdown()?;
        println!("asked the server at {addr} to shut down");
        return Ok(());
    }
    let kind = match args.str_or("kind", "solve").as_str() {
        "solve" => RequestKind::Solve,
        "sweep" => RequestKind::Sweep {
            grid: GridSpec {
                lambda1: args.f64_list_or("l1", &[0.2, 0.3, 0.45])?,
                lambda2: args.f64_list_or("l2", &[0.0, 0.1])?,
            },
            per_point: args.has("per-point"),
        },
        "stability" => RequestKind::Stability {
            stab: StabilityConfig {
                subsamples: args.usize_or("subsamples", 8)?,
                fraction: args.f64_or("fraction", 0.5)?,
                threshold: args.f64_or("stab-threshold", 0.7)?,
                seed: args.u64_or("stab-seed", 0)?,
                ..StabilityConfig::default()
            },
        },
        other => return Err(anyhow!("unknown --kind {other:?} (solve|sweep|stability)")),
    };
    let req = EstimationRequest::from_args(kind, args, &file_cfg)?;
    let density = args.f64_or("select-density", 0.1)?;
    let mut client = Client::connect(&addr)?;
    let job = client.submit(&req, None, density)?;
    println!("submitted job {job} to {addr}");
    client.wait(job)?;
    let bill = client.bill(job)?;
    println!(
        "job {job} done: modeled {:.4}s (screening {}: {:.4}s)",
        bill.f64_or("total_time", 0.0)?,
        if bill.bool_or("screen_cached", false)? { "cached" } else { "cold" },
        bill.f64_or("screen_time", 0.0)?
    );
    let out_omega = args.str_or("out-omega", "");
    if !out_omega.is_empty() {
        let text = client.result_omega(job)?;
        std::fs::write(&out_omega, text)
            .map_err(|e| anyhow!("writing omega to {out_omega}: {e}"))?;
        println!("wrote omega to {out_omega}");
    }
    Ok(())
}

/// `convert`: generate the named workload and write its X to an HPCX
/// file for later `--x-file` runs. The write is atomic (temp file +
/// rename), and the fresh file is reopened through the validating
/// reader so a convert that prints a summary is known readable.
fn cmd_convert(args: &Args) -> Result<()> {
    let file_cfg = load_config(args)?;
    let out = args.str_or("out", "");
    if out.is_empty() {
        return Err(anyhow!("convert requires --out FILE (the HPCX path to write)"));
    }
    let problem = WorkloadSpec::from_args(args, &file_cfg)?.generate()?;
    let path = std::path::PathBuf::from(&out);
    io::write_x(&path, &problem.x)?;
    let xd = XDisk::open(&path)?;
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "wrote {}: HPCX v{} n={} p={} ({bytes} bytes)",
        path.display(),
        io::X_VERSION,
        xd.rows(),
        xd.cols()
    );
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let shape = ProblemShape {
        p: args.f64_or("p", 40_000.0)?,
        n: args.f64_or("n", 100.0)?,
        s: args.f64_or("s", 40.0)?,
        t: args.f64_or("t", 10.0)?,
        d: args.f64_or("d", 10.0)?,
    };
    let procs = args.usize_or("procs", 512)?;
    let threads = node_threads(args, &Config::default())?;
    // The Lemma 3.5 pricing reads the installed tile's cache-reuse term.
    tile::install(tile_config(args, &Config::default())?);
    let variant = parse_variant(&args.str_or("variant", "auto"));
    // Per-ISA pricing: γ_dense is divided by the lane's measured
    // speedup over the scalar blocked kernel (BENCH_simd_baseline.json)
    // — the planner itself stays lane-agnostic.
    let kernel = kernel_lane(args, &Config::default())?;
    let machine = MachineParams::default().with_dense_rate_scale(kernel.gamma_scale());
    let best = hpconcord::cost::optimizer::optimize_replication_threaded(
        &shape,
        procs,
        variant,
        &machine,
        f64::INFINITY,
        threads,
    )
    .ok_or_else(|| anyhow!("no feasible configuration"))?;
    println!(
        "best: {:?} with c_X={} c_Ω={} (t={threads} node threads, {} lane) → modeled {:.4}s \
         (mem {:.1} MWords/proc)",
        best.variant,
        best.choice.c_x,
        best.choice.c_omega,
        kernel.resolve().as_str(),
        best.time,
        best.cost.memory_words / 1e6
    );
    let naive = hpconcord::cost::optimizer::evaluate(
        &shape,
        &hpconcord::cost::ReplicationChoice { p_procs: procs, c_x: 1, c_omega: 1 },
        best.variant,
    )
    .time_with_threads(&machine, procs, threads);
    println!("vs c_X=c_Ω=1: {:.4}s → replication speedup {:.2}×", naive, naive / best.time);
    Ok(())
}

fn cmd_fmri(args: &Args) -> Result<()> {
    let params = hpconcord::coordinator::FmriParams {
        p_hemi: args.usize_or("p-hemi", 96)?,
        parcels: args.usize_or("parcels", 5)?,
        samples: args.usize_or("samples", 200)?,
        seed: args.u64_or("seed", 7)?,
        ..Default::default()
    };
    let out = hpconcord::coordinator::run_fmri_study(&params);
    println!(
        "selected λ1={} λ2={} (density {:.4} vs target {:.4}); \
         cross-hemisphere nnz fraction {:.4}",
        out.lambda1, out.lambda2, out.density, out.target_density, out.cross_hemisphere_fraction
    );
    let mut table = Table::new(&["hemisphere", "method", "clusters", "Jaccard vs truth"]);
    for s in &out.scores {
        table.row(vec![
            (if s.hemisphere == 0 { "left" } else { "right" }).to_string(),
            s.method.clone(),
            format!("{}", s.clusters),
            format!("{:.4}", s.jaccard),
        ]);
    }
    print!("{table}");
    Ok(())
}

fn cmd_engine(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let mut engine = Engine::load(&dir)?;
    let mut names = engine.names().into_iter().map(String::from).collect::<Vec<_>>();
    names.sort();
    println!("{} artifacts in {dir}:", names.len());
    for n in &names {
        println!("  {n}");
    }
    // Smoke: run a trial artifact against the native twin.
    if let Some(&p) = engine.trial_sizes().first() {
        let mut rng = Rng::new(1);
        let prob = gen::chain_problem(p, 50, &mut rng);
        let s = hpconcord::runtime::native::gram(&prob.x);
        let omega = Mat::eye(p);
        let w = hpconcord::runtime::native::w_step(&omega, &s);
        let (grad, g0) = hpconcord::runtime::native::gradobj(&omega, &w, 0.1);
        let pjrt = engine.trial(&omega, &grad, &s, g0, 0.5, 0.3, 0.1)?;
        let native = hpconcord::runtime::native::trial(&omega, &grad, &s, g0, 0.5, 0.3, 0.1);
        let diff = pjrt.omega_new.max_abs_diff(&native.omega_new);
        println!("trial_p{p} PJRT vs native: max |Δ| = {diff:.3e}");
        if diff > 1e-9 {
            return Err(anyhow!("PJRT/native mismatch"));
        }
        println!("engine smoke OK");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(str::to_string).collect();
        Args::parse(&argv)
    }

    #[test]
    fn per_point_outside_dist_mode_is_a_clean_error() {
        for cmd in ["sweep --screen --per-point", "sweep --screen --mode single --per-point"] {
            let err = sweep_mode(&parse(cmd)).unwrap_err();
            assert!(err.to_string().contains("--mode dist"), "{cmd}: {err}");
        }
    }

    #[test]
    fn unknown_sweep_mode_is_a_clean_error() {
        let err = sweep_mode(&parse("sweep --mode cluster")).unwrap_err();
        assert!(err.to_string().contains("unknown --mode"), "{err}");
    }

    #[test]
    fn unknown_solve_mode_is_a_clean_error() {
        let err = solve_mode(&parse("solve --mode quantum")).unwrap_err();
        assert!(err.to_string().contains("unknown --mode"), "{err}");
        assert_eq!(solve_mode(&parse("solve --mode dist")).unwrap(), "dist");
    }

    #[test]
    fn valid_sweep_modes_pass() {
        assert_eq!(sweep_mode(&parse("sweep")).unwrap(), "single");
        assert_eq!(sweep_mode(&parse("sweep --screen --mode dist --per-point")).unwrap(), "dist");
    }

    #[test]
    fn x_file_outside_screened_dist_is_a_clean_error() {
        for (mode, screen) in [("single", false), ("single", true), ("dist", false)] {
            let err = validate_x_file_mode(Some("x.xbin"), mode, screen).unwrap_err();
            assert!(
                err.to_string().contains("--mode dist"),
                "mode {mode} screen {screen}: {err}"
            );
        }
        validate_x_file_mode(Some("x.xbin"), "dist", true).unwrap();
        // No x-file: every mode is fine.
        validate_x_file_mode(None, "single", false).unwrap();
    }

    #[test]
    fn x_file_resolves_from_cli_over_config() {
        let req = EstimationRequest::from_args(
            RequestKind::Solve,
            &parse("solve --x-file cli.xbin"),
            &Config::default(),
        )
        .unwrap();
        assert_eq!(req.x_file.as_deref(), Some("cli.xbin"));
        let req =
            EstimationRequest::from_args(RequestKind::Solve, &parse("solve"), &Config::default())
                .unwrap();
        assert_eq!(req.x_file, None);
    }

    /// The serve flags validate before anything binds: a bad address is
    /// caught without touching the network, and the global budgets ride
    /// the same fail-fast path.
    #[test]
    fn serve_flags_validate_before_binding() {
        let err = serve_options(&parse("serve --addr nonsense"), &Config::default()).unwrap_err();
        assert!(err.to_string().contains("host:port"), "{err}");
        let ok = serve_options(
            &parse("serve --addr 127.0.0.1:0 --ranks-budget 4 --mem-budget 100000"),
            &Config::default(),
        )
        .unwrap();
        assert_eq!(ok.addr, "127.0.0.1:0");
        assert_eq!(ok.ranks_budget, 4);
        assert_eq!(ok.mem_budget, 100_000);
    }

    #[test]
    fn client_kind_validates_before_connecting() {
        let err = cmd_client(&parse("client --kind spiral --addr 127.0.0.1:1")).unwrap_err();
        assert!(err.to_string().contains("unknown --kind"), "{err}");
    }
}
