//! Measurement helpers for the `harness = false` benches (criterion is
//! not vendored in this offline image): warmup + repeated timing with
//! min/median/mean reporting.

use std::time::Instant;

/// Summary of repeated timings, in seconds.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
    pub reps: usize,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> BenchStats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        BenchStats {
            min: samples[0],
            median: samples[n / 2],
            mean: samples.iter().sum::<f64>() / n as f64,
            max: samples[n - 1],
            reps: n,
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min {:.4}s  median {:.4}s  mean {:.4}s  (n={})",
            self.min, self.median, self.mean, self.reps
        )
    }
}

/// Time `f` `reps` times after `warmup` unmeasured runs. The closure's
/// result is returned from the last rep to keep the work observable.
pub fn time_fn<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> (BenchStats, T) {
    assert!(reps > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        last = Some(out);
    }
    (BenchStats::from_samples(samples), last.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn time_fn_runs_and_returns() {
        let mut count = 0;
        let (stats, out) = time_fn(1, 3, || {
            count += 1;
            count
        });
        assert_eq!(stats.reps, 3);
        assert_eq!(out, 4); // 1 warmup + 3 reps
        assert!(stats.min >= 0.0);
    }
}
