//! Structured `BENCH_*.json` performance records.
//!
//! ROADMAP item 1 demands that every "faster" claim become a *measured*
//! claim with a recorded trajectory. This module is the funnel: the
//! `harness = false` benches (`perf_hotpath`, the fig benches) build
//! [`BenchRecord`]s — bench name, problem shape, threads/tile knobs,
//! GFLOP/s, wall seconds, the bit-identity oracle that guarded the
//! number — and a [`BenchRecorder`] serializes them (hand-rolled JSON;
//! serde is not vendored offline) stamped with the git revision, UTC
//! date and host facts, so records from different containers and
//! revisions stay comparable.
//!
//! Activation: benches always collect; they write only when the
//! `BENCH_RECORD` environment variable or the `--record` bench flag is
//! set, so plain `cargo bench` runs stay side-effect free. The committed
//! `BENCH_baseline.json` at the repo root follows this exact schema.
//!
//! ```no_run
//! use hpconcord::util::bench_record::{BenchRecord, BenchRecorder};
//!
//! let mut rec = BenchRecorder::new("perf_hotpath");
//! rec.push(BenchRecord {
//!     name: "gemm_blocked".into(),
//!     shape: "p=512".into(),
//!     threads: 1,
//!     tile: "128,256,512".into(),
//!     gflops: 3.2,
//!     wall_s: 0.084,
//!     reps: 5,
//!     oracle: "bitwise == matmul_naive".into(),
//! });
//! if rec.enabled() {
//!     let path = rec.write().unwrap();
//!     eprintln!("wrote {}", path.display());
//! }
//! ```

use std::path::PathBuf;
use std::process::Command;

use anyhow::{anyhow, Result};

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Benchmark name, e.g. `gemm_blocked` or `spmm_mt`.
    pub name: String,
    /// Problem shape, e.g. `p=512` or `p=1024 density=0.02`.
    pub shape: String,
    /// Node-local thread count the number was measured at.
    pub threads: usize,
    /// Cache-blocking tile `mc,kc,nc`, or `-` when not applicable.
    pub tile: String,
    /// Throughput; 0.0 when a rate is not meaningful for this bench.
    pub gflops: f64,
    /// Median wall seconds over `reps` measured repetitions.
    pub wall_s: f64,
    /// Number of measured repetitions behind `wall_s`.
    pub reps: usize,
    /// The equivalence assertion that guarded this number (empty when
    /// the bench has no oracle), e.g. `bitwise == matmul_naive`.
    pub oracle: String,
}

/// Collects [`BenchRecord`]s and writes one `BENCH_<bench>.json`.
#[derive(Debug, Default)]
pub struct BenchRecorder {
    bench: String,
    records: Vec<BenchRecord>,
}

impl BenchRecorder {
    pub fn new(bench: &str) -> BenchRecorder {
        BenchRecorder { bench: bench.to_string(), records: Vec::new() }
    }

    pub fn push(&mut self, record: BenchRecord) {
        self.records.push(record);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// True when this run should persist records: the `BENCH_RECORD`
    /// env var is set (its value is the output file or directory) or
    /// the bench was invoked with `--record`.
    pub fn enabled(&self) -> bool {
        std::env::var_os("BENCH_RECORD").is_some() || std::env::args().any(|a| a == "--record")
    }

    /// Output path: `$BENCH_RECORD` if it names a file (`.json`), else
    /// `BENCH_<bench>.json` under `$BENCH_RECORD` as a directory, else
    /// `BENCH_<bench>.json` in the working directory.
    pub fn out_path(&self) -> PathBuf {
        let default_name = format!("BENCH_{}.json", self.bench);
        match std::env::var_os("BENCH_RECORD") {
            Some(v) if !v.is_empty() => {
                let p = PathBuf::from(&v);
                if p.extension().is_some_and(|e| e == "json") {
                    p
                } else {
                    p.join(default_name)
                }
            }
            _ => PathBuf::from(default_name),
        }
    }

    /// Serialize every record with the run's provenance stamp.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        push_kv(&mut out, 1, "bench", &self.bench, true);
        push_kv(&mut out, 1, "git_rev", &git_rev(), true);
        push_kv(&mut out, 1, "date", &utc_date(), true);
        push_kv(&mut out, 1, "harness", "rust cargo-bench harness", true);
        out.push_str("  \"host\": {\n");
        push_kv(&mut out, 2, "os", std::env::consts::OS, true);
        push_kv(&mut out, 2, "arch", std::env::consts::ARCH, true);
        let cpus = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        out.push_str(&format!("    \"cpus\": {cpus}\n  }},\n"));
        out.push_str("  \"records\": [\n");
        for (k, r) in self.records.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"name\": {}, ", json_str(&r.name)));
            out.push_str(&format!("\"shape\": {}, ", json_str(&r.shape)));
            out.push_str(&format!("\"threads\": {}, ", r.threads));
            out.push_str(&format!("\"tile\": {}, ", json_str(&r.tile)));
            out.push_str(&format!("\"gflops\": {}, ", json_num(r.gflops)));
            out.push_str(&format!("\"wall_s\": {}, ", json_num(r.wall_s)));
            out.push_str(&format!("\"reps\": {}, ", r.reps));
            out.push_str(&format!("\"oracle\": {}", json_str(&r.oracle)));
            out.push_str(if k + 1 < self.records.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write `to_json()` to [`out_path`](Self::out_path).
    pub fn write(&self) -> Result<PathBuf> {
        let path = self.out_path();
        std::fs::write(&path, self.to_json())
            .map_err(|e| anyhow!("writing bench records to {}: {e}", path.display()))?;
        Ok(path)
    }
}

fn push_kv(out: &mut String, indent: usize, key: &str, val: &str, comma: bool) {
    out.push_str(&"  ".repeat(indent));
    out.push_str(&format!("\"{key}\": {}{}\n", json_str(val), if comma { "," } else { "" }));
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON numbers may not be NaN/Inf; clamp those to 0 (a bench that
/// produced one has already failed its assert).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn git_rev() -> String {
    Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}

fn utc_date() -> String {
    Command::new("date")
        .args(["-u", "+%Y-%m-%dT%H:%M:%SZ"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| {
            // Fallback: raw epoch seconds, still totally ordered.
            let secs = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0);
            format!("epoch+{secs}s")
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchRecord {
        BenchRecord {
            name: "gemm_blocked".into(),
            shape: "p=512".into(),
            threads: 2,
            tile: "128,256,512".into(),
            gflops: 3.25,
            wall_s: 0.0826,
            reps: 5,
            oracle: "bitwise == matmul_naive".into(),
        }
    }

    #[test]
    fn json_contains_every_field_and_stamp_keys() {
        let mut rec = BenchRecorder::new("perf_hotpath");
        rec.push(sample());
        let json = rec.to_json();
        for key in [
            "\"bench\": \"perf_hotpath\"",
            "\"git_rev\"",
            "\"date\"",
            "\"host\"",
            "\"name\": \"gemm_blocked\"",
            "\"shape\": \"p=512\"",
            "\"threads\": 2",
            "\"tile\": \"128,256,512\"",
            "\"gflops\": 3.25",
            "\"wall_s\": 0.0826",
            "\"reps\": 5",
            "\"oracle\": \"bitwise == matmul_naive\"",
        ] {
            assert!(json.contains(key), "missing {key} in:\n{json}");
        }
    }

    #[test]
    fn records_are_comma_separated_and_balanced() {
        let mut rec = BenchRecorder::new("x");
        rec.push(sample());
        rec.push(sample());
        let json = rec.to_json();
        assert_eq!(json.matches("\"name\"").count(), 2);
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn string_escaping() {
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_num(f64::NAN), "0");
        assert_eq!(json_num(1.5), "1.5");
    }

    #[test]
    fn out_path_defaults_to_bench_name() {
        let rec = BenchRecorder::new("perf_hotpath");
        // Do not read BENCH_RECORD here: other tests in the process may
        // run with it set; only the default (unset) shape is pinned.
        if std::env::var_os("BENCH_RECORD").is_none() {
            assert_eq!(rec.out_path(), PathBuf::from("BENCH_perf_hotpath.json"));
        }
    }
}
