//! Small self-contained utilities standing in for crates unavailable in
//! this offline environment: benchmark timing/statistics and structured
//! `BENCH_*.json` performance records (no criterion),
//! an ASCII table printer for the paper-figure benches, a property
//! testing harness (no proptest), and the deterministic node-local
//! thread pool (no rayon) that backs the parallel linear algebra layer.

pub mod bench;
pub mod bench_record;
pub mod pool;
pub mod proptest;
pub mod table;

pub use bench::{time_fn, BenchStats};
pub use bench_record::{BenchRecord, BenchRecorder};
pub use table::Table;
