//! Small self-contained utilities standing in for crates unavailable in
//! this offline environment: benchmark timing/statistics (no criterion),
//! an ASCII table printer for the paper-figure benches, and a property
//! testing harness (no proptest).

pub mod bench;
pub mod proptest;
pub mod table;

pub use bench::{time_fn, BenchStats};
pub use table::Table;
