//! A tiny property-testing harness (proptest is not vendored offline):
//! run a property over many seeded random cases, report the first
//! failing seed for reproduction. Used by the invariant tests on
//! routing/layout/solver state.

use crate::rng::Rng;

/// Run `prop(case_rng)` for `cases` independent seeds derived from
/// `seed`; panics with the failing case's seed on the first violation.
pub fn check(seed: u64, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed on case {case} (seed {case_seed}): {msg}");
        }
    }
}

/// Assert helper producing a `Result` for [`check`] properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(1, 25, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(2, 10, |rng| {
            let v = rng.uniform();
            if v >= 0.0 {
                Err("always fails".to_string())
            } else {
                Ok(())
            }
        });
    }
}
