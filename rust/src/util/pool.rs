//! Deterministic scoped thread pool for node-local parallelism — the
//! paper's "threaded MKL on every node" (§4 runs 24 threads per node;
//! the Lemma 3.1–3.5 flop terms are divided by the per-node thread
//! count t).
//!
//! Design rules, chosen so every parallel kernel is **bit-for-bit
//! identical to its serial twin at any thread count**:
//!
//! - work is partitioned by contiguous *row ranges* (optionally aligned,
//!   e.g. to the packed GEMM's microkernel height `linalg::tile::MR` so
//!   only the trailing chunk runs ragged slabs — a perf nicety; the
//!   blocked kernels' per-element ascending-k order makes the bits
//!   partition-independent regardless) and every output element is
//!   written by exactly one worker running the unmodified serial inner
//!   loop — no atomics, no reduction races;
//! - scalar reductions never combine in thread order: callers reduce
//!   over *fixed-size blocks* (see `ops::REDUCE_BLOCK_ROWS`) whose
//!   partials are concatenated by block index, so the combination order
//!   is a function of the problem shape only, never of `threads`;
//! - workers are `std::thread::scope` threads; chunk 0 runs on the
//!   calling thread. This file itself spells no `unsafe`: the only
//!   platform call it makes — optional worker→CPU pinning — lives in
//!   the vendored `affinity` shim (see `vendor/affinity`), which with
//!   `linalg/simd.rs` is the tree's whole unsafe surface
//!   (`tools/static_audit.py` check 14).
//!
//! The entry points are [`chunk_ranges`] (the partition), [`par_map`]
//! (gather per-chunk results in chunk order) and [`par_rows_mut`]
//! (write disjoint row ranges of one output buffer in place).
//!
//! ## Core pinning (`--pin-cores`)
//!
//! With [`set_pin_cores`]`(true)`, each spawned worker pins itself to
//! logical CPU `chunk_index % available_parallelism` before running,
//! so the mc×kc packed panels a worker touches stop migrating between
//! per-core L2s mid-solve. Chunk 0 is **never** pinned: it runs on the
//! calling thread, and `sched_setaffinity` outlives the call — pinning
//! it would leak a one-core mask into the rest of the process.
//! Pinning is schedule-only (determinism rule 10): the partition and
//! every per-chunk op sequence are unchanged, so bits cannot move; on
//! unsupported platforms or denied masks it silently no-ops.

/// Minimum work (output elements × inner length, or nnz·n for SpMM)
/// below which the `_mt` kernels stay serial: a scoped spawn+join
/// cycle costs tens of microseconds, which dwarfs the loop bodies on
/// the small per-rank slabs the simulated fabric produces (e.g. 4-row
/// prox slabs run per line-search trial). Serial and parallel paths
/// are bit-identical, so the cutoff never changes results — only
/// where the wall-clock win starts.
pub const SPAWN_MIN_WORK: usize = 1 << 16;

use std::sync::atomic::{AtomicBool, Ordering};

/// Process-wide `--pin-cores` switch (default off). Like
/// `linalg::tile::install`, concurrent writers are benign: pinning is
/// schedule-only, so a racing reader only gains or loses the affinity
/// hint, never a bit.
static PIN_CORES: AtomicBool = AtomicBool::new(false);

/// Enable or disable worker→CPU pinning for subsequent pool launches
/// (the solvers install `ConcordConfig::pin_cores` on entry).
pub fn set_pin_cores(pin: bool) {
    PIN_CORES.store(pin, Ordering::Relaxed);
}

/// Whether worker pinning is currently enabled.
pub fn pin_cores() -> bool {
    PIN_CORES.load(Ordering::Relaxed)
}

/// Pin the calling worker to its chunk's CPU if `--pin-cores` is on.
/// Failures (unsupported platform, restricted cpuset) are ignored:
/// the worker just runs unpinned.
fn maybe_pin(chunk_index: usize) {
    if !PIN_CORES.load(Ordering::Relaxed) {
        return;
    }
    let cpus = std::thread::available_parallelism().map_or(1, |n| n.get());
    let _ = affinity::pin_to_cpu(chunk_index % cpus);
}

/// Split `items` into at most `threads` contiguous ranges with
/// boundaries aligned down to multiples of `align` (the trailing range
/// absorbs the remainder). Ranges may be empty; concatenated in order
/// they cover `0..items` exactly. The partition depends only on
/// `(items, threads, align)` — never on data.
pub fn chunk_ranges(items: usize, threads: usize, align: usize) -> Vec<(usize, usize)> {
    let t = threads.max(1);
    let a = align.max(1);
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    for k in 1..t {
        let ideal = items * k / t;
        let aligned = ideal / a * a;
        let prev = *bounds.last().expect("nonempty");
        bounds.push(aligned.max(prev).min(items));
    }
    bounds.push(items);
    bounds.windows(2).map(|w| (w[0], w[1])).collect()
}

/// Run `f(chunk_index, start, end)` for every non-empty range on its own
/// scoped thread (chunk 0 on the caller) and return the results in
/// chunk order. With one usable chunk this is a plain serial call.
pub fn par_map<T, F>(ranges: &[(usize, usize)], f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize, usize) -> T + Sync,
{
    let work: Vec<(usize, usize, usize)> = ranges
        .iter()
        .enumerate()
        .filter(|(_, (s, e))| e > s)
        .map(|(i, &(s, e))| (i, s, e))
        .collect();
    if work.len() <= 1 {
        return work.into_iter().map(|(i, s, e)| f(i, s, e)).collect();
    }
    std::thread::scope(|scope| {
        let fr = &f;
        let handles: Vec<_> = work[1..]
            .iter()
            .map(|&(i, s, e)| {
                scope.spawn(move || {
                    maybe_pin(i);
                    fr(i, s, e)
                })
            })
            .collect();
        let (i0, s0, e0) = work[0];
        let mut out = vec![fr(i0, s0, e0)];
        for h in handles {
            out.push(h.join().expect("pool worker panicked"));
        }
        out
    })
}

/// Split `out` (a row-major buffer of rows of `row_width` elements) at
/// the given row ranges and run `f(chunk_index, start_row, end_row,
/// chunk_rows)` with each chunk's disjoint sub-slice, concurrently.
/// Ranges must tile `0..out.len()/row_width` (as [`chunk_ranges`]
/// produces).
pub fn par_rows_mut<F>(out: &mut [f64], row_width: usize, ranges: &[(usize, usize)], f: F)
where
    F: Fn(usize, usize, usize, &mut [f64]) + Sync,
{
    let total_rows = ranges.last().map_or(0, |&(_, e)| e);
    assert_eq!(out.len(), total_rows * row_width, "ranges must tile the buffer");
    let mut slices: Vec<(usize, usize, usize, &mut [f64])> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    for (i, &(s, e)) in ranges.iter().enumerate() {
        let (head, tail) = rest.split_at_mut((e - s) * row_width);
        rest = tail;
        if e > s {
            slices.push((i, s, e, head));
        }
    }
    if slices.len() <= 1 {
        for (i, s, e, sl) in slices {
            f(i, s, e, sl);
        }
        return;
    }
    std::thread::scope(|scope| {
        let fr = &f;
        let mut iter = slices.into_iter();
        let first = iter.next().expect("len > 1");
        let handles: Vec<_> = iter
            .map(|(i, s, e, sl)| {
                scope.spawn(move || {
                    maybe_pin(i);
                    fr(i, s, e, sl)
                })
            })
            .collect();
        let (i, s, e, sl) = first;
        fr(i, s, e, sl);
        for h in handles {
            h.join().expect("pool worker panicked");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_tile_and_align() {
        for items in [0usize, 1, 2, 3, 17, 64, 1023] {
            for threads in 1..=8 {
                for align in [1usize, 2, 4] {
                    let r = chunk_ranges(items, threads, align);
                    assert_eq!(r.len(), threads);
                    let mut next = 0;
                    for (i, &(s, e)) in r.iter().enumerate() {
                        assert_eq!(s, next, "items={items} t={threads} a={align}");
                        assert!(e >= s);
                        if i + 1 < r.len() {
                            assert_eq!(e % align, 0, "interior boundary must be aligned");
                        }
                        next = e;
                    }
                    assert_eq!(next, items);
                }
            }
        }
    }

    #[test]
    fn par_map_returns_in_chunk_order() {
        let ranges = chunk_ranges(100, 4, 1);
        let out = par_map(&ranges, |i, s, e| (i, s, e));
        assert_eq!(out.len(), 4);
        for (k, &(i, s, e)) in out.iter().enumerate() {
            assert_eq!(k, i);
            assert_eq!((s, e), ranges[i]);
        }
    }

    #[test]
    fn par_rows_mut_writes_every_row_once() {
        let rows = 37;
        let width = 5;
        let mut buf = vec![0.0f64; rows * width];
        let touched = AtomicUsize::new(0);
        for threads in [1usize, 2, 3, 8] {
            buf.iter_mut().for_each(|v| *v = 0.0);
            touched.store(0, Ordering::SeqCst);
            par_rows_mut(&mut buf, width, &chunk_ranges(rows, threads, 2), |_i, s, e, sl| {
                assert_eq!(sl.len(), (e - s) * width);
                for (r, row) in sl.chunks_exact_mut(width).enumerate() {
                    for v in row.iter_mut() {
                        *v += (s + r) as f64 + 1.0;
                    }
                }
                touched.fetch_add(e - s, Ordering::SeqCst);
            });
            assert_eq!(touched.load(Ordering::SeqCst), rows, "threads={threads}");
            for r in 0..rows {
                for c in 0..width {
                    assert_eq!(buf[r * width + c], r as f64 + 1.0, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn pinning_is_schedule_only() {
        // Same partition, same per-chunk results, with pinning on and
        // off — the knob may only move which core runs a worker.
        let ranges = chunk_ranges(64, 4, 1);
        let run = || par_map(&ranges, |i, s, e| (i, (s..e).map(|v| v as f64).sum::<f64>()));
        let unpinned = run();
        set_pin_cores(true);
        let pinned = run();
        set_pin_cores(false);
        assert_eq!(unpinned, pinned);
    }

    #[test]
    fn empty_work_is_fine() {
        let r = chunk_ranges(0, 4, 2);
        assert!(par_map(&r, |_, _, _| 1).is_empty());
        let mut buf: Vec<f64> = Vec::new();
        par_rows_mut(&mut buf, 3, &r, |_, _, _, _| panic!("no chunks to run"));
    }
}
