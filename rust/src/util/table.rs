//! Minimal ASCII table printer: the benches print the same rows/series
//! the paper's tables and figures report.

/// Column-aligned ASCII table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |f: &mut std::fmt::Formatter<'_>, cells: &[String]| -> std::fmt::Result {
            for (c, cell) in cells.iter().enumerate() {
                write!(f, "| {:width$} ", cell, width = widths[c])?;
            }
            writeln!(f, "|")
        };
        line(f, &self.header)?;
        for (c, w) in widths.iter().enumerate() {
            write!(f, "|{:-<width$}", "", width = w + 2)?;
            if c + 1 == ncol {
                writeln!(f, "|")?;
            }
        }
        for row in &self.rows {
            line(f, row)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["p", "time"]);
        t.row(vec!["10".into(), "1.5".into()]);
        t.row(vec!["1000".into(), "12.25".into()]);
        let s = t.to_string();
        assert!(s.contains("| p    | time  |"), "{s}");
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }
}
