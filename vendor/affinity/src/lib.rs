//! Thread→CPU affinity, vendored (no crates.io access in the offline
//! build image): a thin binding to Linux's `sched_setaffinity(2)` for
//! the pool's `--pin-cores` knob, and a no-op returning `false` on
//! every other platform.
//!
//! Pinning is a **schedule-only** knob (ARCHITECTURE.md determinism
//! rule 10): it decides which core runs a worker, never what the
//! worker computes — so a failed or unsupported pin is silently
//! ignored and the caller just runs unpinned.
//!
//! This file and `rust/src/linalg/simd.rs` are the only places in the
//! tree allowed to spell `unsafe` (`tools/static_audit.py` check 14).
//! The single unsafe block is the FFI call itself; the mask is a local
//! fixed-size bit array matching the kernel's `cpu_set_t` layout
//! (1024 bits), and `pid = 0` addresses the calling thread only.

/// Number of 64-bit words in the affinity mask — 1024 CPUs, the
/// default kernel `CPU_SETSIZE`.
#[cfg(target_os = "linux")]
const MASK_WORDS: usize = 16;

#[cfg(target_os = "linux")]
extern "C" {
    /// `int sched_setaffinity(pid_t pid, size_t cpusetsize, const cpu_set_t *mask)`
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
}

/// Pin the **calling thread** to `cpu` (a 0-based logical CPU index).
/// Returns `true` if the kernel accepted the mask; `false` on any
/// failure, on out-of-range indices, and on non-Linux platforms —
/// callers treat `false` as "run unpinned", never as an error.
#[cfg(target_os = "linux")]
pub fn pin_to_cpu(cpu: usize) -> bool {
    if cpu >= MASK_WORDS * 64 {
        return false;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[cpu / 64] = 1u64 << (cpu % 64);
    // SAFETY: the mask is a live, properly-sized local buffer for the
    // whole call; pid 0 means the calling thread; sched_setaffinity
    // only reads `cpusetsize` bytes from it.
    let rc = unsafe { sched_setaffinity(0, MASK_WORDS * 8, mask.as_ptr()) };
    rc == 0
}

/// Non-Linux platforms: affinity is unsupported; report "not pinned".
#[cfg(not(target_os = "linux"))]
pub fn pin_to_cpu(_cpu: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_of_range_is_refused() {
        assert!(!pin_to_cpu(usize::MAX));
        assert!(!pin_to_cpu(16 * 64));
    }

    #[test]
    fn pinning_is_a_clean_yes_or_no() {
        // On non-Linux this is the documented no-op; on Linux the call
        // succeeds unless the cgroup's cpuset excludes CPU 0 (possible
        // in constrained CI sandboxes). Either answer is legitimate —
        // what the shim guarantees is a panic-free bool, and that a
        // success can only happen where the platform supports it.
        let pinned = pin_to_cpu(0);
        assert!(!pinned || cfg!(target_os = "linux"));
    }
}
