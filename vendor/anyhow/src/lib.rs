//! Offline shim for the subset of the `anyhow` API this repository uses:
//! [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and [`ensure!`]
//! macros (all three real-crate arms: literal, displayable expression
//! and format string + args), plus the [`Context`] extension trait.
//! The real crate is not vendored in the offline image; this one is
//! API-compatible for our call sites so the code reads exactly as it
//! would with crates.io `anyhow`.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what allows the blanket
//! `impl From<E: std::error::Error> for Error` powering `?` conversions.

use std::fmt;

/// A string-chained error: a message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build from anything displayable (the `anyhow!` macro target).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` under a higher-level context message.
    pub fn context(self, msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain, outermost first.
    fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, colon-separated (anyhow's
            // convention, used by the launcher's `error: {e:#}`).
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {}", c.msg)?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Preserve the std cause chain as message context.
        let mut msgs = vec![e.to_string()];
        let mut cause = e.source();
        while let Some(c) = cause {
            msgs.push(c.to_string());
            cause = c.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least the top-level message")
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string, a displayable value, or
/// a format string plus arguments — the real crate's three arms, in the
/// same match order (a bare string literal is a format string, so inline
/// captures like `anyhow!("bad {x}")` work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`]; accepts the same three arms as
/// [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return Err($crate::anyhow!($msg))
    };
    ($err:expr $(,)?) => {
        return Err($crate::anyhow!($err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return Err($crate::anyhow!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds. The bare
/// form stringifies the condition like the real crate does.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $msg:literal $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!($msg));
        }
    };
    ($cond:expr, $err:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!($err));
        }
    };
    ($cond:expr, $fmt:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($fmt, $($arg)*));
        }
    };
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "inner 42");
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn anyhow_and_bail_accept_all_three_arms() {
        fn lit() -> Result<()> {
            bail!("plain literal")
        }
        fn expr() -> Result<()> {
            let owned = String::from("from expression");
            bail!(owned)
        }
        fn fmt() -> Result<()> {
            bail!("x = {}, y = {}", 1, 2)
        }
        assert_eq!(format!("{}", lit().unwrap_err()), "plain literal");
        assert_eq!(format!("{}", expr().unwrap_err()), "from expression");
        assert_eq!(format!("{}", fmt().unwrap_err()), "x = 1, y = 2");
        let e = anyhow!("inline {}", "capture");
        assert_eq!(format!("{e}"), "inline capture");
    }

    #[test]
    fn ensure_stringifies_and_formats() {
        fn bare(v: usize) -> Result<usize> {
            ensure!(v > 2);
            Ok(v)
        }
        fn with_msg(v: usize) -> Result<usize> {
            ensure!(v > 2, "v too small: {v}");
            Ok(v)
        }
        assert_eq!(bare(3).unwrap(), 3);
        let e = bare(1).unwrap_err();
        assert_eq!(format!("{e}"), "Condition failed: `v > 2`");
        let e = with_msg(1).unwrap_err();
        assert_eq!(format!("{e}"), "v too small: 1");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::num::ParseIntError> =
            "z".parse::<usize>().map(|_| ());
        let e = r.context("while parsing").unwrap_err();
        assert_eq!(format!("{e}"), "while parsing");
        let o: Option<u8> = None;
        assert!(o.with_context(|| "missing").is_err());
    }
}
