//! Quickstart: fit HP-CONCORD on a synthetic chain-graph problem, first
//! on a single node, then on a simulated 8-rank cluster with replication,
//! and check support recovery against the ground truth.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hpconcord::concord::{fit_distributed, fit_single_node, ConcordConfig, Variant};
use hpconcord::metrics::support_metrics;
use hpconcord::prelude::*;

fn main() -> anyhow::Result<()> {
    // A p = 128, n = 200 chain-graph problem (paper §4 workload).
    let mut rng = Rng::new(42);
    let problem = gen::chain_problem(128, 200, &mut rng);

    let cfg = ConcordConfig {
        lambda1: 0.35,
        lambda2: 0.1,
        tol: 1e-5,
        variant: Variant::Auto,
        ..Default::default()
    };

    // --- Single node (the BigQUIC head-to-head setting) -----------------
    let t0 = std::time::Instant::now();
    let fit = fit_single_node(&problem.x, &cfg)?;
    let m = support_metrics(&fit.omega, &problem.omega0, 1e-8);
    println!(
        "single node : {} iterations ({:.1} line-search trials each), {:.3}s",
        fit.iterations,
        fit.mean_linesearch,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "              PPV {:.1}%  FDR {:.1}%  recall {:.1}%",
        100.0 * m.ppv,
        100.0 * m.fdr,
        100.0 * m.recall
    );

    // --- Simulated distributed (8 ranks, c_X = 2, c_Ω = 2) --------------
    let out = fit_distributed(&problem.x, &cfg, 8, 2, 2, MachineParams::edison_like());
    let dm = support_metrics(&out.fit.omega, &problem.omega0, 1e-8);
    println!(
        "distributed : variant {:?}, {} iterations, modeled time {:.4}s ({:.4}s comm)",
        out.variant, out.fit.iterations, out.cost.time, out.cost.comm_time
    );
    println!(
        "              max/rank: {} messages, {} words; PPV {:.1}%",
        out.cost.max_per_rank.messages,
        out.cost.max_per_rank.words,
        100.0 * dm.ppv
    );

    // The two paths compute the same estimate.
    let diff = fit.omega.max_abs_diff(&out.fit.omega);
    println!("single-node vs distributed estimate: max |Δ| = {diff:.2e}");
    assert!(diff < 1e-7);
    Ok(())
}
