//! Tuning-grid sweep through the coordinator (the paper's §5 workflow:
//! "running HP-CONCORD on a single (λ1, λ2) pair took ≈37 minutes", so
//! the 88-point grid is an embarrassingly parallel scheduling problem).
//! Demonstrates the leader/worker queue, per-job statistics, and
//! density-targeted model selection.
//!
//! ```bash
//! cargo run --release --example grid_sweep
//! ```

use hpconcord::concord::{ConcordConfig, Variant};
use hpconcord::coordinator::{run_sweep, select_by_density, GridSpec};
use hpconcord::metrics::support_metrics;
use hpconcord::prelude::*;
use hpconcord::util::Table;

fn main() {
    let mut rng = Rng::new(3);
    let problem = gen::random_problem(96, 120, 6, &mut rng);
    let true_density =
        (problem.omega0.nnz() - 96) as f64 / (96.0 * 95.0);

    let grid = GridSpec {
        lambda1: vec![0.15, 0.25, 0.35, 0.5, 0.7],
        lambda2: vec![0.0, 0.1, 0.25],
    };
    let base = ConcordConfig {
        tol: 1e-4,
        max_iter: 150,
        variant: Variant::Cov,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = run_sweep(&problem.x, &grid, &base, 4);
    println!(
        "{} jobs on {} workers in {:.2}s",
        out.results.len(),
        out.workers,
        t0.elapsed().as_secs_f64()
    );

    let mut table = Table::new(&["λ1", "λ2", "iters", "density%", "PPV%", "recall%"]);
    for r in &out.results {
        let m = support_metrics(&r.fit.omega, &problem.omega0, 1e-8);
        table.row(vec![
            format!("{:.2}", r.job.cfg.lambda1),
            format!("{:.2}", r.job.cfg.lambda2),
            format!("{}", r.fit.iterations),
            format!("{:.2}", 100.0 * r.density),
            format!("{:.1}", 100.0 * m.ppv),
            format!("{:.1}", 100.0 * m.recall),
        ]);
    }
    print!("{table}");

    let chosen = select_by_density(&out.results, true_density).unwrap();
    println!(
        "density-matched selection (target {:.2}%): λ1 = {}, λ2 = {}",
        100.0 * true_density,
        chosen.job.cfg.lambda1,
        chosen.job.cfg.lambda2
    );
}
