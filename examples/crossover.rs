//! Cov-vs-Obs crossover (paper Figure 2, scaled): fix p, sweep n, and
//! watch Obs's cost grow linearly in n while Cov's stays flat — then
//! compare where the measured crossover lands against Lemma 3.1's
//! prediction (the paper observes the measured one comes later, because
//! γ_sparse ≫ γ_dense).
//!
//! ```bash
//! cargo run --release --example crossover
//! ```

use hpconcord::concord::{fit_distributed, ConcordConfig, Variant};
use hpconcord::cost::model::cov_is_cheaper_flops;
use hpconcord::cost::ProblemShape;
use hpconcord::prelude::*;
use hpconcord::util::Table;

fn main() {
    let p = 128;
    let ranks = 8;
    let machine = MachineParams::edison_like();
    let mut table = Table::new(&[
        "n", "T_cov (model)", "T_obs (model)", "winner", "Lemma 3.1 says",
    ]);

    for n in [16usize, 32, 64, 128, 256] {
        let mut rng = Rng::new(1000 + n as u64);
        let problem = gen::chain_problem(p, n, &mut rng);
        let cfg = ConcordConfig {
            lambda1: 0.35,
            tol: 1e-4,
            max_iter: 60,
            ..Default::default()
        };

        let run = |variant| {
            let mut c = cfg;
            c.variant = variant;
            fit_distributed(&problem.x, &c, ranks, 2, 2, machine)
        };
        let cov = run(Variant::Cov);
        let obs = run(Variant::Obs);

        // Lemma 3.1 verdict from the measured solver statistics.
        let shape = ProblemShape {
            p: p as f64,
            n: n as f64,
            s: cov.fit.iterations as f64,
            t: cov.fit.mean_linesearch.max(1.0),
            d: cov.fit.mean_row_nnz,
        };
        let lemma = if cov_is_cheaper_flops(&shape) { "Cov" } else { "Obs" };
        let winner = if cov.cost.time < obs.cost.time { "Cov" } else { "Obs" };
        table.row(vec![
            n.to_string(),
            format!("{:.4}s", cov.cost.time),
            format!("{:.4}s", obs.cost.time),
            winner.to_string(),
            lemma.to_string(),
        ]);
    }
    print!("{table}");
    println!("(Obs grows ~linearly with n; Cov stays ~flat — Fig. 2's shape.)");
}
