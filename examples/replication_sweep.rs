//! Replication-factor sweep (paper Figure 3, scaled): run the Obs
//! variant at every feasible (c_X, c_Ω) on a simulated 16-rank machine
//! and print the modeled-runtime heatmap. The (1, 1) cell is the
//! non-communication-avoiding baseline; the best cell's speedup over it
//! is the paper's headline 5× effect.
//!
//! ```bash
//! cargo run --release --example replication_sweep
//! ```

use hpconcord::concord::{fit_distributed, ConcordConfig, Variant};
use hpconcord::prelude::*;
use hpconcord::util::Table;

fn main() {
    let ranks = 16;
    let (p, n) = (128usize, 32usize);
    let mut rng = Rng::new(7);
    let problem = gen::chain_problem(p, n, &mut rng);
    // Fixed iteration budget: the comparison is about communication per
    // iteration, not convergence.
    let cfg = ConcordConfig {
        lambda1: 0.35,
        tol: 0.0,
        max_iter: 8,
        variant: Variant::Obs,
        ..Default::default()
    };
    let machine = MachineParams::edison_like();

    let mut header = vec!["c_Ω \\ c_X".to_string()];
    let mut cxs = Vec::new();
    let mut cx = 1;
    while cx <= ranks {
        header.push(format!("{cx}"));
        cxs.push(cx);
        cx *= 2;
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    let mut best = (f64::INFINITY, 1, 1);
    let mut baseline = f64::NAN;
    let mut co = 1;
    while co <= ranks {
        let mut row = vec![co.to_string()];
        for &cx in &cxs {
            if cx * co > ranks {
                row.push("-".to_string());
                continue;
            }
            let out = fit_distributed(&problem.x, &cfg, ranks, cx, co, machine);
            let t = out.cost.time;
            if cx == 1 && co == 1 {
                baseline = t;
            }
            if t < best.0 {
                best = (t, cx, co);
            }
            row.push(format!("{:.4}", t));
        }
        table.row(row);
        co *= 2;
    }
    print!("{table}");
    println!(
        "worst (c_X=c_Ω=1): {baseline:.4}s; best (c_X={}, c_Ω={}): {:.4}s → {:.2}× speedup",
        best.1,
        best.2,
        best.0,
        baseline / best.0
    );
}
