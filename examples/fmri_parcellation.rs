//! End-to-end driver (paper §5, scaled): the full HP-CONCORD pipeline on
//! a realistic small workload, proving all layers compose —
//!
//!   synthetic cortex (two hemispheres, global BOLD-like confound)
//!     → sample covariance
//!     → coordinator (λ₁, λ₂) grid sweep over the CONCORD solver
//!     → density-matched model selection
//!     → partial-correlation graph
//!     → clustering (persistence watershed, Louvain, covariance baseline)
//!     → modified-Jaccard scores vs the ground-truth parcellation.
//!
//! The run is recorded in EXPERIMENTS.md (§5 case study).
//!
//! ```bash
//! cargo run --release --example fmri_parcellation
//! ```

use hpconcord::coordinator::{run_fmri_study, FmriParams};
use hpconcord::util::Table;

fn main() {
    let params = FmriParams::default(); // p = 2×96 voxels, 5 parcels/hemisphere
    println!(
        "synthetic cortex: p = {} voxels ({} per hemisphere), {} parcels/hemisphere, n = {}",
        2 * params.p_hemi,
        params.p_hemi,
        params.parcels,
        params.samples
    );
    println!(
        "sweeping {} (λ1, λ2) grid points on {} coordinator workers...",
        params.lambda1_grid.len() * params.lambda2_grid.len(),
        params.workers
    );
    let t0 = std::time::Instant::now();
    let out = run_fmri_study(&params);
    let secs = t0.elapsed().as_secs_f64();

    println!(
        "\nselected λ1 = {}, λ2 = {} — off-diagonal density {:.4} (target {:.4})",
        out.lambda1, out.lambda2, out.density, out.target_density
    );
    println!(
        "hemisphere block structure: {:.2}% of estimated edges cross hemispheres \
         (paper §S.3.3: ≈ 0)",
        100.0 * out.cross_hemisphere_fraction
    );

    let mut table = Table::new(&["hemisphere", "method", "clusters", "Jaccard vs truth"]);
    for s in &out.scores {
        table.row(vec![
            (if s.hemisphere == 0 { "left" } else { "right" }).to_string(),
            s.method.clone(),
            format!("{}", s.clusters),
            format!("{:.4}", s.jaccard),
        ]);
    }
    print!("\n{table}");

    // Headline check: partial-correlation clusterings beat the marginal
    // (covariance-threshold) baseline — the paper's §5 comparison.
    for h in 0..2u8 {
        let best_pc = out
            .scores
            .iter()
            .filter(|s| s.hemisphere == h && s.method != "cov-threshold")
            .map(|s| s.jaccard)
            .fold(0.0, f64::max);
        let baseline = out
            .scores
            .iter()
            .find(|s| s.hemisphere == h && s.method == "cov-threshold")
            .map(|s| s.jaccard)
            .unwrap_or(0.0);
        println!(
            "hemisphere {}: best partial-correlation Jaccard {:.4} vs marginal baseline {:.4}",
            if h == 0 { "left " } else { "right" },
            best_pc,
            baseline
        );
    }
    println!("\nend-to-end pipeline completed in {secs:.1}s");
}
